//! Bit-exact, lane-parallel, event-driven netlist simulator.
//!
//! **Representation.** Simulation state is *lane-major*: every net holds
//! one `u64` word whose bit *i* is that net's boolean value in
//! independent lane *i*. A lane is a complete, isolated stimulus stream —
//! one image of a micro-batch — so a single [`Sim::settle`]/[`Sim::tick`]
//! pass evaluates up to [`LANES`] images at once (the same bit-parallel
//! trick the paper's `Conv_3` plays at the operand level with dual-pixel
//! packing, applied here across the whole netlist).
//!
//! **Cycle model** (unchanged from the scalar simulator), two-phase:
//! 1. [`Sim::settle`] — evaluate combinational cells in topological order
//!    from primary inputs, constants, and sequential-cell outputs.
//! 2. [`Sim::tick`] — clock edge: every sequential cell latches its
//!    settled input values; then combinational logic re-settles.
//!
//! **Event-driven settle.** A dense settle evaluates every pre-decoded
//! op every pass even when most of the fabric is quiet. Instead, the
//! simulator schedules on **topological levels with a dirty set**:
//!
//! * At build time every comb op gets a level from
//!   [`Netlist::comb_levels`] (sequential outputs count as sources), and
//!   a CSR net→reader-op map records each net's immediate fanout cone.
//! * At run time the dirty set is seeded by the input setters (only when
//!   a lane word actually changes) and by FF/DSP/RAM output publication
//!   after [`Sim::tick`]. [`Sim::settle`] then sweeps the per-level
//!   queues in ascending order, evaluating only woken ops; an op that
//!   produces a changed word wakes its readers, which the levelization
//!   contract guarantees sit at strictly deeper levels — so each woken
//!   op is evaluated at most once per settle and one ascending sweep
//!   reaches the same fixpoint as the dense pass.
//! * The lane-word `old ^ new` diff the toggle counter already computes
//!   is the change-detection signal, so wakeups are free and
//!   toggle/power accounting stays *exact*: a skipped op's inputs are
//!   bit-identical to its last evaluation, hence its outputs (and their
//!   toggle charges, zero) are too.
//! * Dense full sweeps remain as bootstrap (first settle after load),
//!   as a fallback when the seed set is already a large fraction of the
//!   op list (quiet-fabric wins only exist when the cone is small), as
//!   a forced mode for benchmarking ([`Sim::set_force_dense`]), and as
//!   the `dense-check` debug cross-check ([`Sim::assert_dense_fixpoint`]).
//!
//! [`Sim::settle_stats`] reports the resulting activity (ops evaluated
//! vs. total, wakeups per level, dense vs. event passes) so benches and
//! the layer checks can show how quiet a workload really is.
//!
//! **Per-cell evaluation.**
//! * LUTs evaluate bit-parallel by Shannon mux-tree reduction of the
//!   truth table: the 2^k INIT bits are broadcast to lane words, then
//!   folded by each input's lane word with `(t0 & !x) | (t1 & x)` — 2^k−1
//!   word ops evaluate all 64 lanes, so the per-lane cost *falls* as
//!   occupancy rises. (A 1-lane `Sim` takes the classic index-the-table
//!   scalar path instead, which is cheaper at occupancy 1.)
//! * `Carry8` ripples its 8 stages with pure bitwise ops on lane words
//!   ([`carry8_eval_lanes`]); FDRE is three bitwise ops
//!   ([`fdre_next_lanes`]).
//! * DSP48E2 and RAMB18 keep per-lane architectural state and iterate
//!   only over the live lanes.
//!
//! **Toggle exactness.** Every published word is diffed against the old
//! value and masked by the live-lane mask; `count_ones()` on `old ⊕ new`
//! charges exactly one toggle per lane per transition, so per-net counts
//! equal the sum of the counts that per-lane scalar runs would have
//! produced and the activity-based power model is unchanged at any
//! occupancy (see the differential property tests below, and
//! [`Sim::mean_toggle_rate`] which normalizes per lane).
//!
//! This is the oracle that proves an IP netlist implements its behavioral
//! model: `ips::verify` drives both with the same stimulus — lane-batched
//! via [`Sim::with_lanes`] — and compares outputs cycle by cycle.
//!
//! **Bus-width contract.** Whole-bus accessors ([`Sim::set_input`],
//! [`Sim::get_unsigned`], ...) carry at most 64 bits and assert it;
//! wider buses (e.g. a K²·W window port) must go through the field
//! accessors ([`Sim::set_input_field_at`] and per-element output
//! slices), which is what every driver in the tree already does.

use super::{CellKind, NetId, Netlist, NetlistError};
use crate::fabric::carry::carry8_eval_lanes;
use crate::fabric::dsp48::{self, Dsp48e2, ZMux};
use crate::fabric::ff::fdre_next_lanes;

/// Maximum (and word-width) lane count of one simulator instance: one
/// image per bit of a `u64` lane word.
pub const LANES: usize = 64;

/// With the `dense-check` feature, every Nth [`Sim::settle`] re-evaluates
/// the whole op list read-only and asserts the event-driven result is a
/// dense fixpoint (live-lane values identical). Cheap enough for
/// debug/test builds, never compiled into release benches.
#[cfg(feature = "dense-check")]
const DENSE_CHECK_EVERY: u64 = 16;

/// Pre-decoded sequential element with inline per-lane state (perf:
/// tick() runs allocation-free and in place — DESIGN.md §Perf item 3).
enum FastSeq {
    Ff { d: u32, ce: u32, r: u32, q: u32, state: u64, next: u64 },
    Dsp { ins: Vec<u32>, outs: Vec<u32>, dsps: Vec<Dsp48e2> },
    Ram {
        width: u32,
        wdata: Vec<u32>,
        waddr: Vec<u32>,
        we: u32,
        raddr: Vec<u32>,
        outs: Vec<u32>,
        /// Lane-major contents: entry `lane * depth + addr`.
        depth: usize,
        data: Vec<u64>,
        /// Registered read value per lane.
        rd: Vec<u64>,
    },
}

/// Activity accounting of the settle scheduler, cumulative since
/// construction. `ops_evaluated <= ops_total` always: the levelized
/// sweep evaluates each woken op at most once per settle, and a dense
/// pass evaluates each op exactly once.
#[derive(Debug, Clone, Default)]
pub struct SettleStats {
    /// Total [`Sim::settle`] calls (dense + event).
    pub settles: u64,
    /// Settles that ran the dense full sweep (bootstrap, forced, or
    /// seed-fraction fallback).
    pub dense_settles: u64,
    /// Comb ops actually evaluated across all settles.
    pub ops_evaluated: u64,
    /// Comb ops a dense-only simulator would have evaluated
    /// (`settles * fast.len()`).
    pub ops_total: u64,
    /// Ops woken per topological level, summed over event settles only.
    pub wakeups_per_level: Vec<u64>,
}

impl SettleStats {
    /// Settles that took the event-driven path.
    pub fn event_settles(&self) -> u64 {
        self.settles - self.dense_settles
    }

    /// Fraction of the dense workload actually evaluated (1.0 = every
    /// settle swept every op; small = the fabric was quiet).
    pub fn evaluated_fraction(&self) -> f64 {
        if self.ops_total == 0 {
            return 0.0;
        }
        self.ops_evaluated as f64 / self.ops_total as f64
    }

    /// Activity since `baseline` (an earlier clone of these stats).
    ///
    /// The counters are cumulative over a [`Sim`]'s lifetime, so code
    /// attributing work to an *interval* — e.g. one pipeline pass inside
    /// a multi-pass lane run — must subtract the snapshot it took at the
    /// interval's start or it double-counts everything before it.
    /// Differences saturate at zero so a stale baseline degrades to
    /// "no delta" instead of wrapping.
    pub fn delta_since(&self, baseline: &SettleStats) -> SettleStats {
        let wakeups = self
            .wakeups_per_level
            .iter()
            .enumerate()
            .map(|(i, &w)| w.saturating_sub(baseline.wakeups_per_level.get(i).copied().unwrap_or(0)))
            .collect();
        SettleStats {
            settles: self.settles.saturating_sub(baseline.settles),
            dense_settles: self.dense_settles.saturating_sub(baseline.dense_settles),
            ops_evaluated: self.ops_evaluated.saturating_sub(baseline.ops_evaluated),
            ops_total: self.ops_total.saturating_sub(baseline.ops_total),
            wakeups_per_level: wakeups,
        }
    }

    /// Zero every counter (level histogram keeps its length). Pairs with
    /// [`SettleStats::delta_since`]: reset when a fresh epoch should not
    /// inherit earlier activity.
    pub fn reset(&mut self) {
        self.settles = 0;
        self.dense_settles = 0;
        self.ops_evaluated = 0;
        self.ops_total = 0;
        self.wakeups_per_level.iter_mut().for_each(|w| *w = 0);
    }
}

/// Build-time levelization + fanout index and the run-time dirty set of
/// the event-driven settle.
struct Scheduler {
    /// Topological level of each fast op (parallel to `Sim::fast`).
    op_level: Vec<u32>,
    /// CSR offsets: readers of net `n` are
    /// `user_ops[user_start[n]..user_start[n+1]]`.
    user_start: Vec<u32>,
    /// Flattened fast-op indices, grouped by the net they read.
    user_ops: Vec<u32>,
    /// Woken-op queue per topological level; drained ascending.
    pending: Vec<Vec<u32>>,
    /// Dedup flag per fast op: already sitting in a pending queue.
    queued: Vec<bool>,
    /// Number of ops currently queued across all levels.
    n_queued: usize,
}

impl Scheduler {
    /// Queue every reader of `net` that is not already queued.
    #[inline]
    fn wake_net(&mut self, net: u32) {
        let lo = self.user_start[net as usize] as usize;
        let hi = self.user_start[net as usize + 1] as usize;
        for k in lo..hi {
            let op = self.user_ops[k] as usize;
            if !self.queued[op] {
                self.queued[op] = true;
                self.pending[self.op_level[op] as usize].push(op as u32);
                self.n_queued += 1;
            }
        }
    }

    /// Drop every queued wakeup (a dense sweep just satisfied them all).
    fn clear(&mut self) {
        if self.n_queued == 0 {
            return;
        }
        for q in &mut self.pending {
            for &op in q.iter() {
                self.queued[op as usize] = false;
            }
            q.clear();
        }
        self.n_queued = 0;
    }
}

/// Publish `word` onto `net`, charging toggles for every live lane whose
/// bit changed — `count_ones()` on `old ⊕ new` under the live mask keeps
/// the power model's activity exact at any lane occupancy, and the same
/// diff doubles as the event scheduler's change signal: when `WAKE`,
/// a changed word queues the net's reader ops. The single shared write
/// path of `settle`/`publish_seq_outputs`.
#[inline(always)]
fn publish<const WAKE: bool>(
    values: &mut [u64],
    toggles: &mut [u64],
    live: u64,
    sched: &mut Scheduler,
    net: u32,
    word: u64,
) {
    let slot = &mut values[net as usize];
    let diff = (*slot ^ word) & live;
    *slot = word;
    if diff != 0 {
        toggles[net as usize] += diff.count_ones() as u64;
        if WAKE {
            sched.wake_net(net);
        }
    }
}

/// Drive an input net's lane bits under `mask`. Inputs charge no toggles
/// (stimulus is free, as before), and wakeups fire only when the lane
/// word actually changes — repeated identical stimulus costs no settle
/// work. `wake` is false only in forced-dense mode.
#[inline(always)]
fn drive_net(
    values: &mut [u64],
    sched: &mut Scheduler,
    wake: bool,
    net: u32,
    mask: u64,
    bit_on: bool,
) {
    let slot = &mut values[net as usize];
    let word = if bit_on { *slot | mask } else { *slot & !mask };
    if *slot != word {
        *slot = word;
        if wake {
            sched.wake_net(net);
        }
    }
}

/// Evaluate one comb op from `values` and publish its outputs. With
/// `WAKE`, changed outputs queue their reader ops (the event path);
/// without, outputs publish silently (the dense path — order covers
/// everything anyway).
fn eval_op<const WAKE: bool>(
    op: &FastOp,
    scalar: bool,
    values: &mut [u64],
    toggles: &mut [u64],
    live: u64,
    sched: &mut Scheduler,
) {
    match op {
        FastOp::Lut { ins, funcs } => {
            if scalar {
                // Occupancy-1 fast path: classic index-the-table.
                let mut idx = 0usize;
                for (i, &n) in ins.iter().enumerate() {
                    idx |= ((values[n as usize] & 1) as usize) << i;
                }
                for &(init, out) in funcs {
                    publish::<WAKE>(values, toggles, live, sched, out, (init >> idx) & 1);
                }
            } else {
                let mut x = [0u64; 6];
                for (i, &n) in ins.iter().enumerate() {
                    x[i] = values[n as usize];
                }
                for &(init, out) in funcs {
                    let word = lut_eval_lanes(init, &x[..ins.len()]);
                    publish::<WAKE>(values, toggles, live, sched, out, word);
                }
            }
        }
        FastOp::Carry { s, di, ci, o, co } => {
            let mut sv = [0u64; 8];
            let mut dv = [0u64; 8];
            for i in 0..8 {
                sv[i] = values[s[i] as usize];
                dv[i] = values[di[i] as usize];
            }
            let (ov, cv) = carry8_eval_lanes(&sv, &dv, values[*ci as usize]);
            for i in 0..8 {
                publish::<WAKE>(values, toggles, live, sched, o[i], ov[i]);
                publish::<WAKE>(values, toggles, live, sched, co[i], cv[i]);
            }
        }
    }
}

/// Simulator instance bound to a checked netlist.
pub struct Sim<'nl> {
    nl: &'nl Netlist,
    /// Pre-decoded combinational ops in topological order (perf: avoids
    /// per-cycle CellKind matching and NetId indirection — see
    /// DESIGN.md §Perf items 2–3).
    fast: Vec<FastOp>,
    /// Pre-decoded sequential elements with inline state.
    fastseq: Vec<FastSeq>,
    /// Bus-name resolution built once at construction, so the per-cycle
    /// setters/getters never clone a bus or scan the port lists.
    input_ix: std::collections::HashMap<String, usize>,
    output_ix: std::collections::HashMap<String, usize>,
    /// Live lane count (1..=LANES) and its bit mask.
    lanes: usize,
    live: u64,
    /// Lane word per net: bit i = the net's value in lane i.
    values: Vec<u64>,
    toggles: Vec<u64>,
    cycles: u64,
    /// Event-driven settle machinery (levels, fanout CSR, dirty queues).
    sched: Scheduler,
    stats: SettleStats,
    /// Next settle must be a dense sweep: no fixpoint established yet
    /// (fresh build, or wakes were suppressed by forced-dense mode).
    bootstrap: bool,
    /// Benchmark/debug mode: every settle sweeps densely and wakeups are
    /// suppressed ([`Sim::set_force_dense`]).
    force_dense: bool,
}

/// Pre-decoded combinational operation.
enum FastOp {
    /// Plain or fractured LUT: gather input lane words by flat net index,
    /// reduce the truth table(s).
    Lut { ins: Vec<u32>, funcs: Vec<(u64, u32)> }, // (init, out_net)
    /// Carry chain: (s[8], di[8], ci, o[8], co[8]) as flat net indices.
    Carry { s: [u32; 8], di: [u32; 8], ci: u32, o: [u32; 8], co: [u32; 8] },
}

impl FastOp {
    /// Visit every input net this op reads (the edges the fanout CSR
    /// indexes).
    fn for_each_input(&self, mut f: impl FnMut(u32)) {
        match self {
            FastOp::Lut { ins, .. } => {
                for &n in ins {
                    f(n);
                }
            }
            FastOp::Carry { s, di, ci, .. } => {
                for &n in s {
                    f(n);
                }
                for &n in di {
                    f(n);
                }
                f(*ci);
            }
        }
    }
}

/// Evaluate one LUT truth table over all lanes at once: broadcast each
/// INIT bit to a full/empty lane word, then Shannon-fold by each input's
/// lane word. 2^k−1 word muxes evaluate up to 64 lanes.
#[inline]
fn lut_eval_lanes(init: u64, xs: &[u64]) -> u64 {
    debug_assert!((1..=6).contains(&xs.len()), "LUT arity {}", xs.len());
    let n = 1usize << xs.len();
    let mut tab = [0u64; 64];
    for (j, t) in tab.iter_mut().enumerate().take(n) {
        *t = 0u64.wrapping_sub((init >> j) & 1); // all-ones / all-zeros
    }
    let mut size = n;
    for &x in xs {
        size >>= 1;
        for j in 0..size {
            tab[j] = (tab[2 * j] & !x) | (tab[2 * j + 1] & x);
        }
    }
    tab[0]
}

/// Gather one lane's integer value from a list of net lane words.
#[inline]
fn bits_lane(values: &[u64], nets: &[u32], lane: usize) -> u64 {
    let mut v = 0u64;
    for (i, &n) in nets.iter().enumerate() {
        v |= ((values[n as usize] >> lane) & 1) << i;
    }
    v
}

/// [`bits_lane`] as a signed (two's complement) value.
#[inline]
fn signed_lane(values: &[u64], nets: &[u32], lane: usize) -> i64 {
    crate::fixed::pack::sign_extend(bits_lane(values, nets, lane) as i64, nets.len() as u32)
}

impl<'nl> Sim<'nl> {
    /// Build a single-lane (scalar) simulator; runs [`Netlist::check`].
    pub fn new(nl: &'nl Netlist) -> Result<Self, NetlistError> {
        Sim::with_lanes(nl, 1)
    }

    /// Build a `lanes`-lane simulator (1..=[`LANES`]); every lane is an
    /// independent stimulus stream evaluated by the same settle/tick
    /// passes. Runs [`Netlist::check`].
    pub fn with_lanes(nl: &'nl Netlist, lanes: usize) -> Result<Self, NetlistError> {
        assert!(
            (1..=LANES).contains(&lanes),
            "lane count {lanes} outside 1..={LANES}"
        );
        let live = if lanes == LANES { u64::MAX } else { (1u64 << lanes) - 1 };
        let order = nl.check()?;
        let mut fastseq = Vec::new();
        for c in &nl.cells {
            match &c.kind {
                CellKind::Fdre => fastseq.push(FastSeq::Ff {
                    d: c.ins[0].0,
                    ce: c.ins[1].0,
                    r: c.ins[2].0,
                    q: c.outs[0].0,
                    state: 0,
                    next: 0,
                }),
                CellKind::Dsp48e2 { cfg } => fastseq.push(FastSeq::Dsp {
                    ins: c.ins.iter().map(|n| n.0).collect(),
                    outs: c.outs.iter().map(|n| n.0).collect(),
                    dsps: vec![Dsp48e2::new(*cfg); lanes],
                }),
                CellKind::Ramb18 { width, depth } => {
                    let w = *width as usize;
                    assert!(w <= 64, "RAMB18 width {w} > 64 unsupported");
                    let ab = super::ram_addr_bits(*depth);
                    fastseq.push(FastSeq::Ram {
                        width: *width,
                        wdata: c.ins[0..w].iter().map(|n| n.0).collect(),
                        waddr: c.ins[w..w + ab].iter().map(|n| n.0).collect(),
                        we: c.ins[w + ab].0,
                        raddr: c.ins[w + ab + 1..w + ab + 1 + ab].iter().map(|n| n.0).collect(),
                        outs: c.outs.iter().map(|n| n.0).collect(),
                        depth: *depth as usize,
                        data: vec![0; *depth as usize * lanes],
                        rd: vec![0; lanes],
                    });
                }
                _ => {}
            }
        }
        // Pre-decode the comb order into flat ops. Constants are written
        // once here (broadcast across live lanes) and never re-evaluated.
        // Each op carries its topological level for the event scheduler.
        let cell_levels = nl.comb_levels(&order);
        let mut values = vec![0u64; nl.n_nets()];
        let mut fast = Vec::new();
        let mut op_level = Vec::new();
        for &cid in &order {
            let cell = nl.cell(cid);
            match &cell.kind {
                CellKind::Lut { funcs } => {
                    fast.push(FastOp::Lut {
                        ins: cell.ins.iter().map(|n| n.0).collect(),
                        funcs: funcs
                            .iter()
                            .zip(&cell.outs)
                            .map(|(f, o)| (f.init, o.0))
                            .collect(),
                    });
                    op_level.push(cell_levels[cid.0 as usize]);
                }
                CellKind::Carry8 => {
                    let g = |i: usize| cell.ins[i].0;
                    let h = |i: usize| cell.outs[i].0;
                    fast.push(FastOp::Carry {
                        s: std::array::from_fn(|i| g(i)),
                        di: std::array::from_fn(|i| g(8 + i)),
                        ci: g(16),
                        o: std::array::from_fn(|i| h(i)),
                        co: std::array::from_fn(|i| h(8 + i)),
                    });
                    op_level.push(cell_levels[cid.0 as usize]);
                }
                CellKind::Const { value } => {
                    values[cell.outs[0].0 as usize] = if *value { live } else { 0 }
                }
                CellKind::Input { .. } => {}
                _ => unreachable!("sequential in comb order"),
            }
        }
        // Fanout CSR: net -> indices of the fast ops that read it.
        let n_nets = nl.n_nets();
        let mut user_start = vec![0u32; n_nets + 1];
        for op in &fast {
            op.for_each_input(|n| user_start[n as usize + 1] += 1);
        }
        for i in 0..n_nets {
            user_start[i + 1] += user_start[i];
        }
        let mut user_ops = vec![0u32; user_start[n_nets] as usize];
        let mut cursor = user_start.clone();
        for (oi, op) in fast.iter().enumerate() {
            op.for_each_input(|n| {
                let c = &mut cursor[n as usize];
                user_ops[*c as usize] = oi as u32;
                *c += 1;
            });
        }
        let n_levels = op_level.iter().map(|&l| l as usize + 1).max().unwrap_or(1);
        let sched = Scheduler {
            op_level,
            user_start,
            user_ops,
            pending: vec![Vec::new(); n_levels],
            queued: vec![false; fast.len()],
            n_queued: 0,
        };
        let input_ix =
            nl.inputs.iter().enumerate().map(|(i, (n, _))| (n.clone(), i)).collect();
        let output_ix =
            nl.outputs.iter().enumerate().map(|(i, (n, _))| (n.clone(), i)).collect();
        let mut sim = Sim {
            nl,
            fast,
            fastseq,
            input_ix,
            output_ix,
            lanes,
            live,
            values,
            toggles: vec![0; nl.n_nets()],
            cycles: 0,
            sched,
            stats: SettleStats { wakeups_per_level: vec![0; n_levels], ..Default::default() },
            bootstrap: true,
            force_dense: false,
        };
        sim.publish_seq_outputs();
        sim.settle();
        Ok(sim)
    }

    /// Live lane count of this instance.
    pub fn lanes(&self) -> usize {
        self.lanes
    }

    /// Resolve a declared input bus name to its index (for the `_at`
    /// setters in hot loops). Panics if `name` is not a declared input.
    pub fn input_index(&self, name: &str) -> usize {
        *self.input_ix.get(name).unwrap_or_else(|| panic!("no input named '{name}'"))
    }

    /// Resolve a declared output bus name to its index. Panics if `name`
    /// is not a declared output.
    pub fn output_index(&self, name: &str) -> usize {
        *self.output_ix.get(name).unwrap_or_else(|| panic!("no output named '{name}'"))
    }

    /// Set a primary input bus (LSB-first nets) to an integer value in
    /// EVERY live lane (broadcast — the natural shape for shared control
    /// and coefficient streams). Panics if `name` is not a declared
    /// input or the bus is wider than 64 bits.
    pub fn set_input(&mut self, name: &str, value: u64) {
        self.set_input_at(self.input_index(name), value);
    }

    /// [`Self::set_input`] by pre-resolved index — allocation- and
    /// lookup-free, for per-cycle driver loops.
    pub fn set_input_at(&mut self, input: usize, value: u64) {
        let nl = self.nl; // reborrow at 'nl, independent of &mut self
        let (name, bus) = &nl.inputs[input];
        assert!(
            bus.len() <= 64,
            "input '{name}' is {} bits wide (> 64): drive it with the field accessors",
            bus.len()
        );
        let live = self.live;
        let wake = !self.force_dense;
        let values = &mut self.values;
        let sched = &mut self.sched;
        for (i, net) in bus.iter().enumerate() {
            drive_net(values, sched, wake, net.0, live, (value >> i) & 1 == 1);
        }
    }

    /// Set one lane of a primary input bus, leaving the other lanes
    /// untouched — the per-image setter of a lane-batched driver.
    pub fn set_input_lane(&mut self, name: &str, lane: usize, value: u64) {
        self.set_input_lane_at(self.input_index(name), lane, value);
    }

    /// [`Self::set_input_lane`] by pre-resolved index.
    pub fn set_input_lane_at(&mut self, input: usize, lane: usize, value: u64) {
        let nl = self.nl;
        let (name, bus) = &nl.inputs[input];
        assert!(
            bus.len() <= 64,
            "input '{name}' is {} bits wide (> 64): drive it with the field accessors",
            bus.len()
        );
        assert!(lane < self.lanes, "lane {lane} out of range ({} lanes)", self.lanes);
        let bit = 1u64 << lane;
        let wake = !self.force_dense;
        let values = &mut self.values;
        let sched = &mut self.sched;
        for (i, net) in bus.iter().enumerate() {
            drive_net(values, sched, wake, net.0, bit, (value >> i) & 1 == 1);
        }
    }

    /// Set a contiguous field `[lo, lo+width)` of a (possibly >64-bit)
    /// input bus in every live lane. Used to pack K×K windows element by
    /// element.
    pub fn set_input_field(&mut self, name: &str, lo: usize, width: usize, value: u64) {
        self.set_input_field_at(self.input_index(name), lo, width, value);
    }

    /// [`Self::set_input_field`] by pre-resolved index.
    pub fn set_input_field_at(&mut self, input: usize, lo: usize, width: usize, value: u64) {
        let nl = self.nl;
        let (name, bus) = &nl.inputs[input];
        assert!(width <= 64, "field width {width} > 64 on '{name}'");
        assert!(lo + width <= bus.len(), "field [{lo},{}) exceeds '{name}'", lo + width);
        let live = self.live;
        let wake = !self.force_dense;
        let values = &mut self.values;
        let sched = &mut self.sched;
        for i in 0..width {
            drive_net(values, sched, wake, bus[lo + i].0, live, (value >> i) & 1 == 1);
        }
    }

    /// Set a contiguous field of an input bus in ONE lane — the
    /// per-image window packer of the lane-batched verify drivers.
    pub fn set_input_field_lane_at(
        &mut self,
        input: usize,
        lane: usize,
        lo: usize,
        width: usize,
        value: u64,
    ) {
        let nl = self.nl;
        let (name, bus) = &nl.inputs[input];
        assert!(width <= 64, "field width {width} > 64 on '{name}'");
        assert!(lo + width <= bus.len(), "field [{lo},{}) exceeds '{name}'", lo + width);
        assert!(lane < self.lanes, "lane {lane} out of range ({} lanes)", self.lanes);
        let bit = 1u64 << lane;
        let wake = !self.force_dense;
        let values = &mut self.values;
        let sched = &mut self.sched;
        for i in 0..width {
            drive_net(values, sched, wake, bus[lo + i].0, bit, (value >> i) & 1 == 1);
        }
    }

    /// Read a bus as an unsigned integer in lane 0 (the scalar view).
    /// Panics on buses wider than 64 bits — slice them field-wise.
    pub fn get_unsigned(&self, bus: &[NetId]) -> u64 {
        self.get_unsigned_lane(bus, 0)
    }

    /// Read a bus as an unsigned integer in one lane.
    pub fn get_unsigned_lane(&self, bus: &[NetId], lane: usize) -> u64 {
        assert!(
            bus.len() <= 64,
            "bus is {} bits wide (> 64): read it through field slices",
            bus.len()
        );
        assert!(lane < self.lanes, "lane {lane} out of range ({} lanes)", self.lanes);
        let mut v = 0u64;
        for (i, net) in bus.iter().enumerate() {
            v |= ((self.values[net.0 as usize] >> lane) & 1) << i;
        }
        v
    }

    /// Read a bus as a signed (two's complement) integer in lane 0.
    pub fn get_signed(&self, bus: &[NetId]) -> i64 {
        self.get_signed_lane(bus, 0)
    }

    /// Read a bus as a signed integer in one lane.
    pub fn get_signed_lane(&self, bus: &[NetId], lane: usize) -> i64 {
        let raw = self.get_unsigned_lane(bus, lane);
        let w = bus.len() as u32;
        crate::fixed::pack::sign_extend(raw as i64, w)
    }

    /// Read a declared output by name (signed, lane 0).
    pub fn output_signed(&self, name: &str) -> i64 {
        self.output_signed_at(self.output_index(name))
    }

    /// Read a declared output by name (unsigned, lane 0).
    pub fn output_unsigned(&self, name: &str) -> u64 {
        self.output_unsigned_at(self.output_index(name))
    }

    /// [`Self::output_signed`] by pre-resolved index.
    pub fn output_signed_at(&self, output: usize) -> i64 {
        self.output_signed_lane_at(output, 0)
    }

    /// [`Self::output_unsigned`] by pre-resolved index.
    pub fn output_unsigned_at(&self, output: usize) -> u64 {
        self.output_unsigned_lane_at(output, 0)
    }

    /// Read a declared output in one lane (signed).
    pub fn output_signed_lane_at(&self, output: usize, lane: usize) -> i64 {
        self.get_signed_lane(&self.nl.outputs[output].1, lane)
    }

    /// Read a declared output in one lane (unsigned).
    pub fn output_unsigned_lane_at(&self, output: usize, lane: usize) -> u64 {
        self.get_unsigned_lane(&self.nl.outputs[output].1, lane)
    }

    /// Propagate combinational logic to a fixed point. All lanes settle
    /// in the same pass.
    ///
    /// Takes the event-driven path (levelized sweep of the dirty set)
    /// unless this is the bootstrap settle, dense mode is forced, or the
    /// seed set already covers ≥ 25% of the op list — at that occupancy
    /// the dense sweep's branch-free march over the flat op array beats
    /// queue bookkeeping, which keeps full-activity workloads within a
    /// few percent of the PR 3 baseline while quiet workloads skip
    /// almost everything.
    pub fn settle(&mut self) {
        self.stats.settles += 1;
        self.stats.ops_total += self.fast.len() as u64;
        let dense =
            self.force_dense || self.bootstrap || self.sched.n_queued * 4 >= self.fast.len();
        if dense {
            self.settle_dense();
            self.bootstrap = false;
        } else {
            self.settle_event();
        }
        #[cfg(feature = "dense-check")]
        {
            if self.stats.settles % DENSE_CHECK_EVERY == 0 {
                self.assert_dense_fixpoint();
            }
        }
    }

    /// Dense full sweep: evaluate every op in topological order. No
    /// wakeups — the order itself covers every dependency — and any
    /// queued wakeups are satisfied by the sweep, so the dirty set is
    /// cleared afterwards.
    fn settle_dense(&mut self) {
        self.stats.dense_settles += 1;
        self.stats.ops_evaluated += self.fast.len() as u64;
        let values = &mut self.values;
        let toggles = &mut self.toggles;
        let live = self.live;
        let scalar = self.lanes == 1;
        let sched = &mut self.sched;
        for op in &self.fast {
            eval_op::<false>(op, scalar, values, toggles, live, sched);
        }
        sched.clear();
    }

    /// Event-driven sweep: drain the per-level queues in ascending
    /// order. Evaluating a level-L op can only wake strictly deeper
    /// levels (the [`Netlist::comb_levels`] contract), so each queue is
    /// complete when its level is reached and each woken op is evaluated
    /// exactly once.
    fn settle_event(&mut self) {
        let values = &mut self.values;
        let toggles = &mut self.toggles;
        let live = self.live;
        let scalar = self.lanes == 1;
        let fast = &self.fast;
        let sched = &mut self.sched;
        let mut evaluated = 0u64;
        for lvl in 0..sched.pending.len() {
            let mut q = std::mem::take(&mut sched.pending[lvl]);
            self.stats.wakeups_per_level[lvl] += q.len() as u64;
            for &op in &q {
                sched.queued[op as usize] = false;
                eval_op::<true>(&fast[op as usize], scalar, values, toggles, live, sched);
                evaluated += 1;
            }
            q.clear();
            sched.pending[lvl] = q; // hand the allocation back
        }
        sched.n_queued = 0;
        self.stats.ops_evaluated += evaluated;
    }

    /// Cumulative scheduler activity (ops evaluated vs. dense workload,
    /// wakeups per level, dense/event pass split).
    pub fn settle_stats(&self) -> &SettleStats {
        &self.stats
    }

    /// Force (or release) dense full sweeps on every settle. While
    /// forced, wakeups are suppressed entirely so the dense path pays
    /// zero scheduler overhead — the honest PR 3 baseline for benches.
    /// Releasing the mode re-bootstraps: the next settle sweeps densely
    /// once to re-establish the fixpoint the suppressed wakeups would
    /// have maintained.
    pub fn set_force_dense(&mut self, dense: bool) {
        if self.force_dense && !dense {
            self.bootstrap = true;
        }
        self.force_dense = dense;
    }

    /// Debug cross-check: re-evaluate every comb op read-only and assert
    /// the current values are a dense fixpoint on the live lanes. Panics
    /// on divergence (an event-scheduling bug). O(fast.len()), no state
    /// change.
    pub fn assert_dense_fixpoint(&self) {
        let values = &self.values;
        let live = self.live;
        let scalar = self.lanes == 1;
        for (oi, op) in self.fast.iter().enumerate() {
            match op {
                FastOp::Lut { ins, funcs } => {
                    if scalar {
                        let mut idx = 0usize;
                        for (i, &n) in ins.iter().enumerate() {
                            idx |= ((values[n as usize] & 1) as usize) << i;
                        }
                        for &(init, out) in funcs {
                            check_net(values, live, oi, out, (init >> idx) & 1);
                        }
                    } else {
                        let mut x = [0u64; 6];
                        for (i, &n) in ins.iter().enumerate() {
                            x[i] = values[n as usize];
                        }
                        for &(init, out) in funcs {
                            check_net(values, live, oi, out, lut_eval_lanes(init, &x[..ins.len()]));
                        }
                    }
                }
                FastOp::Carry { s, di, ci, o, co } => {
                    let mut sv = [0u64; 8];
                    let mut dv = [0u64; 8];
                    for i in 0..8 {
                        sv[i] = values[s[i] as usize];
                        dv[i] = values[di[i] as usize];
                    }
                    let (ov, cv) = carry8_eval_lanes(&sv, &dv, values[*ci as usize]);
                    for i in 0..8 {
                        check_net(values, live, oi, o[i], ov[i]);
                        check_net(values, live, oi, co[i], cv[i]);
                    }
                }
            }
        }
    }

    /// Clock edge: latch every sequential cell from settled values, then
    /// re-settle combinational logic. Runs allocation-free: phase 1 reads
    /// settled nets and updates inline state, phase 2 publishes outputs
    /// (a two-phase split so FF->FF shift chains latch atomically).
    /// FDREs latch all lanes with three bitwise ops; DSP and RAM state
    /// advances per live lane. Changed sequential outputs seed the
    /// event scheduler's dirty set for the re-settle.
    pub fn tick(&mut self) {
        self.cycles += 1;
        // Phase 1: compute next states from the settled snapshot.
        let values = &self.values;
        let lanes = self.lanes;
        for op in &mut self.fastseq {
            match op {
                FastSeq::Ff { d, ce, r, q: _, state, next } => {
                    *next = fdre_next_lanes(
                        *state,
                        values[*d as usize],
                        values[*ce as usize],
                        values[*r as usize],
                    );
                }
                FastSeq::Dsp { ins, outs: _, dsps } => {
                    for (lane, dsp) in dsps.iter_mut().enumerate() {
                        let a = signed_lane(values, &ins[0..27], lane);
                        let b = signed_lane(values, &ins[27..45], lane);
                        let c = signed_lane(values, &ins[45..93], lane);
                        let d = signed_lane(values, &ins[93..120], lane);
                        let zmux = match bits_lane(values, &ins[120..122], lane) {
                            0 => ZMux::Zero,
                            1 => ZMux::P,
                            _ => ZMux::C,
                        };
                        let ce = (values[ins[122] as usize] >> lane) & 1 == 1;
                        dsp.clock(dsp48::Inputs { a, b, c, d, zmux, ce });
                    }
                }
                FastSeq::Ram { width, wdata, waddr, we, raddr, outs: _, depth, data, rd } => {
                    let w = *width as usize;
                    let m = if w >= 64 { u64::MAX } else { (1u64 << w) - 1 };
                    for lane in 0..lanes {
                        let wd = bits_lane(values, wdata, lane);
                        let wa = bits_lane(values, waddr, lane) as usize;
                        let ra = bits_lane(values, raddr, lane) as usize;
                        let base = lane * *depth;
                        // Read-old semantics: capture before the write lands.
                        rd[lane] = data[base + ra % *depth];
                        if (values[*we as usize] >> lane) & 1 == 1 {
                            data[base + wa % *depth] = wd & m;
                        }
                    }
                }
            }
        }
        for op in &mut self.fastseq {
            if let FastSeq::Ff { state, next, .. } = op {
                *state = *next;
            }
        }
        // Phase 2: publish sequential outputs and re-settle.
        self.publish_seq_outputs();
        self.settle();
    }

    fn publish_seq_outputs(&mut self) {
        if self.force_dense {
            self.publish_seq_outputs_impl::<false>();
        } else {
            self.publish_seq_outputs_impl::<true>();
        }
    }

    fn publish_seq_outputs_impl<const WAKE: bool>(&mut self) {
        let values = &mut self.values;
        let toggles = &mut self.toggles;
        let live = self.live;
        let lanes = self.lanes;
        let sched = &mut self.sched;
        for op in &self.fastseq {
            match op {
                FastSeq::Ff { q, state, .. } => {
                    publish::<WAKE>(values, toggles, live, sched, *q, *state)
                }
                FastSeq::Dsp { outs, dsps, .. } => {
                    // Transpose per-lane P values into output lane words.
                    let mut outw = [0u64; 48];
                    for (lane, dsp) in dsps.iter().enumerate().take(lanes) {
                        let p = dsp.p() as u64;
                        for (i, w) in outw.iter_mut().enumerate() {
                            *w |= ((p >> i) & 1) << lane;
                        }
                    }
                    for (i, &net) in outs.iter().enumerate() {
                        publish::<WAKE>(values, toggles, live, sched, net, outw[i]);
                    }
                }
                FastSeq::Ram { outs, rd, .. } => {
                    let mut outw = [0u64; 64];
                    for (lane, &v) in rd.iter().enumerate().take(lanes) {
                        for (i, w) in outw.iter_mut().enumerate().take(outs.len()) {
                            *w |= ((v >> i) & 1) << lane;
                        }
                    }
                    for (i, &net) in outs.iter().enumerate() {
                        publish::<WAKE>(values, toggles, live, sched, net, outw[i]);
                    }
                }
            }
        }
    }

    /// Cycles simulated so far (one per [`Self::tick`], regardless of
    /// occupancy — a full 64-lane tick is still one hardware cycle).
    pub fn cycles(&self) -> u64 {
        self.cycles
    }

    /// Total toggles across all nets and live lanes — equals the sum a
    /// set of per-lane scalar runs would have produced (the differential
    /// property tests assert this exactly).
    pub fn toggle_total(&self) -> u64 {
        self.toggles.iter().sum()
    }

    /// Mean toggle rate per net per cycle *per lane* — feeds the dynamic
    /// power model. At 1 lane this is the classic scalar definition; at
    /// higher occupancy it is the average activity of the lanes.
    pub fn mean_toggle_rate(&self) -> f64 {
        if self.cycles == 0 || self.toggles.is_empty() {
            return 0.0;
        }
        let total = self.toggle_total();
        total as f64 / (self.toggles.len() as f64 * self.cycles as f64 * self.lanes as f64)
    }
}

/// Assert one net's value equals an independently re-evaluated word on
/// the live lanes (the `assert_dense_fixpoint` comparator).
fn check_net(values: &[u64], live: u64, op: usize, net: u32, want: u64) {
    let got = values[net as usize];
    assert!(
        (got ^ want) & live == 0,
        "event/dense divergence at op {op}, net {net}: got {got:#x}, want {want:#x}"
    );
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::lut::Lut;
    use crate::netlist::builder::Builder;
    use crate::netlist::Netlist;
    use crate::util::prop::forall;
    use crate::util::rng::Rng;

    /// Build: y = a XOR b, z = register(y).
    fn xor_reg() -> Netlist {
        let mut nl = Netlist::new();
        let a = nl.net();
        let b = nl.net();
        let y = nl.net();
        let q = nl.net();
        let one = nl.net();
        let zero = nl.net();
        nl.add_cell(CellKind::Input { name: "a".into() }, vec![], vec![a]);
        nl.add_cell(CellKind::Input { name: "b".into() }, vec![], vec![b]);
        nl.add_cell(CellKind::Const { value: true }, vec![], vec![one]);
        nl.add_cell(CellKind::Const { value: false }, vec![], vec![zero]);
        nl.add_cell(CellKind::Lut { funcs: vec![Lut::xor2()] }, vec![a, b], vec![y]);
        nl.add_cell(CellKind::Fdre, vec![y, one, zero], vec![q]);
        nl.inputs.push(("a".into(), vec![a]));
        nl.inputs.push(("b".into(), vec![b]));
        nl.outputs.push(("y".into(), vec![y]));
        nl.outputs.push(("q".into(), vec![q]));
        nl
    }

    #[test]
    fn comb_and_register() {
        let nl = xor_reg();
        let mut sim = Sim::new(&nl).unwrap();
        sim.set_input("a", 1);
        sim.set_input("b", 0);
        sim.settle();
        assert_eq!(sim.output_unsigned("y"), 1);
        assert_eq!(sim.output_unsigned("q"), 0, "register not yet clocked");
        sim.tick();
        assert_eq!(sim.output_unsigned("q"), 1);
        sim.set_input("b", 1);
        sim.settle();
        assert_eq!(sim.output_unsigned("y"), 0);
        assert_eq!(sim.output_unsigned("q"), 1, "holds until edge");
        sim.tick();
        assert_eq!(sim.output_unsigned("q"), 0);
    }

    #[test]
    fn signed_bus_read() {
        let mut nl = Netlist::new();
        let nets: Vec<_> = (0..4).map(|_| nl.net()).collect();
        for (i, &n) in nets.iter().enumerate() {
            nl.add_cell(CellKind::Const { value: i == 3 }, vec![], vec![n]); // 0b1000 = -8
        }
        nl.outputs.push(("v".into(), nets.clone()));
        let sim = Sim::new(&nl).unwrap();
        assert_eq!(sim.output_signed("v"), -8);
        assert_eq!(sim.output_unsigned("v"), 8);
    }

    #[test]
    fn toggle_counting() {
        let nl = xor_reg();
        let mut sim = Sim::new(&nl).unwrap();
        for i in 0..10 {
            sim.set_input("a", i & 1);
            sim.set_input("b", 0);
            sim.settle();
            sim.tick();
        }
        assert!(sim.mean_toggle_rate() > 0.0);
        assert_eq!(sim.cycles(), 10);
    }

    #[test]
    fn dsp_cell_macc_via_netlist() {
        use crate::fabric::dsp48::Config;
        let mut nl = Netlist::new();
        let a: Vec<_> = (0..27).map(|_| nl.net()).collect();
        let b: Vec<_> = (0..18).map(|_| nl.net()).collect();
        let c: Vec<_> = (0..48).map(|_| nl.net()).collect();
        let d: Vec<_> = (0..27).map(|_| nl.net()).collect();
        let zm: Vec<_> = (0..2).map(|_| nl.net()).collect();
        let ce = nl.net();
        let p: Vec<_> = (0..48).map(|_| nl.net()).collect();
        for (name, bus) in [("a", &a), ("b", &b), ("c", &c), ("d", &d), ("zm", &zm)] {
            for &n in bus.iter() {
                nl.add_cell(CellKind::Input { name: name.into() }, vec![], vec![n]);
            }
            nl.inputs.push((name.into(), bus.to_vec()));
        }
        nl.add_cell(CellKind::Const { value: true }, vec![], vec![ce]);
        let mut ins = a.clone();
        ins.extend(&b);
        ins.extend(&c);
        ins.extend(&d);
        ins.extend(&zm);
        ins.push(ce);
        nl.add_cell(CellKind::Dsp48e2 { cfg: Config::full_macc(false) }, ins, vec![p.clone()].concat());
        nl.outputs.push(("p".into(), p));
        let mut sim = Sim::new(&nl).unwrap();
        // MAC 3*4 then 5*6, flush 3 cycles.
        let vals = [(3i64, 4i64, 0u64), (5, 6, 1), (0, 0, 1), (0, 0, 1), (0, 0, 1)];
        for (av, bv, zmv) in vals {
            sim.set_input("a", (av as u64) & ((1 << 27) - 1));
            sim.set_input("b", (bv as u64) & ((1 << 18) - 1));
            sim.set_input("c", 0);
            sim.set_input("d", 0);
            sim.set_input("zm", zmv);
            sim.settle();
            sim.tick();
        }
        assert_eq!(sim.output_signed("p"), 3 * 4 + 5 * 6);
    }

    #[test]
    fn bram_cell_roundtrip() {
        let mut nl = Netlist::new();
        let wdata: Vec<_> = (0..8).map(|_| nl.net()).collect();
        let waddr: Vec<_> = (0..4).map(|_| nl.net()).collect();
        let we = nl.net();
        let raddr: Vec<_> = (0..4).map(|_| nl.net()).collect();
        let rdata: Vec<_> = (0..8).map(|_| nl.net()).collect();
        for (name, bus) in [("wdata", &wdata), ("waddr", &waddr), ("raddr", &raddr)] {
            for &n in bus.iter() {
                nl.add_cell(CellKind::Input { name: name.into() }, vec![], vec![n]);
            }
            nl.inputs.push((name.into(), bus.to_vec()));
        }
        nl.add_cell(CellKind::Input { name: "we".into() }, vec![], vec![we]);
        nl.inputs.push(("we".into(), vec![we]));
        let mut ins = wdata.clone();
        ins.extend(&waddr);
        ins.push(we);
        ins.extend(&raddr);
        nl.add_cell(CellKind::Ramb18 { width: 8, depth: 16 }, ins, rdata.clone());
        nl.outputs.push(("rdata".into(), rdata));
        let mut sim = Sim::new(&nl).unwrap();
        sim.set_input("wdata", 0xCD);
        sim.set_input("waddr", 5);
        sim.set_input("we", 1);
        sim.set_input("raddr", 5);
        sim.settle();
        sim.tick(); // write lands; read of OLD value (0) captured
        sim.set_input("we", 0);
        sim.settle();
        sim.tick(); // read of 0xCD captured into rd reg
        assert_eq!(sim.output_unsigned("rdata"), 0xCD);
    }

    // ---------------- lane-parallel coverage ----------------

    #[test]
    fn prop_lut_lane_eval_matches_table_lookup() {
        forall("lut_eval_lanes == per-lane lookup", 400, |g| {
            let k = g.usize_in(1, 6);
            let table_bits = 1usize << k;
            // Draw the INIT in 16-bit chunks to keep draws shrinkable.
            let mut init = 0u64;
            for chunk in 0..table_bits.div_ceil(16) {
                init |= (g.i64_in(0, 0xFFFF) as u64) << (chunk * 16);
            }
            if table_bits < 64 {
                init &= (1u64 << table_bits) - 1;
            }
            let xs: Vec<u64> = (0..k)
                .map(|_| {
                    // Two 32-bit halves per lane word.
                    ((g.i64_in(0, u32::MAX as i64) as u64) << 32)
                        | (g.i64_in(0, u32::MAX as i64) as u64)
                })
                .collect();
            let word = lut_eval_lanes(init, &xs);
            for lane in 0..64 {
                let mut idx = 0u64;
                for (i, x) in xs.iter().enumerate() {
                    idx |= ((x >> lane) & 1) << i;
                }
                let want = (init >> idx) & 1;
                if (word >> lane) & 1 != want {
                    return Err(format!("k={k} init={init:#x} lane={lane}"));
                }
            }
            Ok(())
        });
    }

    /// Build a random arithmetic circuit: outputs `s` (a±b), `p`
    /// (pipelined a*b) and `q` (registered sum) over random widths.
    fn random_arith(wa: usize, wb: usize, sub: bool, cut: bool) -> Netlist {
        let mut nl = Netlist::new();
        let mut b = Builder::new(&mut nl);
        let a_bus = b.input("a", wa);
        let b_bus = b.input("b", wb);
        let s = if sub { b.sub(&a_bus, &b_bus) } else { b.add(&a_bus, &b_bus) };
        let ce = b.one();
        let r = b.zero();
        let cuts: &[usize] = if cut { &[1] } else { &[] };
        let (p, _) = b.mul_signed(&a_bus, &b_bus, cuts, ce, r);
        let q = b.register(&s, ce, r);
        b.output("s", &s);
        b.output("p", &p);
        b.output("q", &q);
        nl
    }

    /// Differential property: a `lanes`-lane Sim must be cycle-for-cycle
    /// bit-identical to `lanes` independent scalar Sims — outputs AND
    /// exact toggle totals (the power-model contract).
    #[test]
    fn prop_lane_sim_matches_scalar_sims() {
        forall("lane sim == scalar sims", 25, |g| {
            let wa = g.usize_in(2, 8);
            let wb = g.usize_in(2, 8);
            let sub = g.bool();
            let cut = g.bool();
            let lanes = g.usize_in(2, 8);
            let cycles = g.usize_in(2, 6);
            let nl = random_arith(wa, wb, sub, cut);
            // Per-lane stimulus streams.
            let stim: Vec<Vec<(i64, i64)>> = (0..lanes)
                .map(|_| {
                    (0..cycles)
                        .map(|_| (g.signed_bits(wa as u32), g.signed_bits(wb as u32)))
                        .collect()
                })
                .collect();
            let amask = (1u64 << wa) - 1;
            let bmask = (1u64 << wb) - 1;
            let mut lane_sim = Sim::with_lanes(&nl, lanes).unwrap();
            let mut scalars: Vec<Sim> = (0..lanes).map(|_| Sim::new(&nl).unwrap()).collect();
            let outs = ["s", "p", "q"];
            for t in 0..cycles {
                for (lane, s) in stim.iter().enumerate() {
                    let (av, bv) = s[t];
                    lane_sim.set_input_lane("a", lane, (av as u64) & amask);
                    lane_sim.set_input_lane("b", lane, (bv as u64) & bmask);
                    scalars[lane].set_input("a", (av as u64) & amask);
                    scalars[lane].set_input("b", (bv as u64) & bmask);
                }
                lane_sim.settle();
                for sc in scalars.iter_mut() {
                    sc.settle();
                }
                for name in outs {
                    let ox = lane_sim.output_index(name);
                    for (lane, sc) in scalars.iter().enumerate() {
                        let got = lane_sim.output_signed_lane_at(ox, lane);
                        let want = sc.output_signed(name);
                        if got != want {
                            return Err(format!(
                                "wa={wa} wb={wb} sub={sub} cut={cut} t={t} lane={lane} {name}: {got} != {want}"
                            ));
                        }
                    }
                }
                lane_sim.tick();
                for sc in scalars.iter_mut() {
                    sc.tick();
                }
            }
            // Toggle exactness: lane total == sum of scalar totals, and
            // the normalized rate is the scalar rates' exact mean.
            let scalar_total: u64 = scalars.iter().map(|s| s.toggle_total()).sum();
            if lane_sim.toggle_total() != scalar_total {
                return Err(format!(
                    "toggle totals diverge: lane={} scalar-sum={scalar_total}",
                    lane_sim.toggle_total()
                ));
            }
            let denom = nl.n_nets() as f64 * lane_sim.cycles() as f64 * lanes as f64;
            if lane_sim.mean_toggle_rate() != scalar_total as f64 / denom {
                return Err("mean_toggle_rate not the exact per-lane mean".into());
            }
            Ok(())
        });
    }

    #[test]
    fn full_occupancy_dsp_lanes_independent() {
        use crate::fabric::dsp48::Config;
        // One DSP in MACC mode, 64 lanes each accumulating a different
        // pair sequence; every lane must match its own scalar model.
        let mut nl = Netlist::new();
        let mut b = Builder::new(&mut nl);
        let a = b.input("a", 8);
        let bb = b.input("b", 8);
        let zm = b.input("zm", 2);
        let c = b.const_bus(0, 48);
        let d = b.const_bus(0, 27);
        let ce = b.one();
        let p = b.dsp(Config::full_macc(false), &a, &bb, &c, &d, &zm, ce);
        b.output("p", &p);
        let mut sim = Sim::with_lanes(&nl, LANES).unwrap();
        let a_ix = sim.input_index("a");
        let b_ix = sim.input_index("b");
        let mut rng = Rng::new(21);
        let pairs: Vec<Vec<(i64, i64)>> = (0..LANES)
            .map(|_| (0..4).map(|_| (rng.signed_bits(8), rng.signed_bits(8))).collect())
            .collect();
        for t in 0..4 + 3 {
            for (lane, seq) in pairs.iter().enumerate() {
                let (av, bv) = if t < 4 { seq[t] } else { (0, 0) };
                sim.set_input_lane_at(a_ix, lane, (av as u64) & 0xFF);
                sim.set_input_lane_at(b_ix, lane, (bv as u64) & 0xFF);
            }
            sim.set_input("zm", if t == 0 { 0 } else { 1 });
            sim.settle();
            sim.tick();
        }
        let p_ix = sim.output_index("p");
        for (lane, seq) in pairs.iter().enumerate() {
            let want: i64 = seq.iter().map(|&(x, y)| x * y).sum();
            assert_eq!(sim.output_signed_lane_at(p_ix, lane), want, "lane {lane}");
        }
    }

    #[test]
    fn bram_lanes_have_independent_contents() {
        // Reuse the roundtrip netlist shape at 8 lanes: each lane writes
        // a different byte at a different address and must read back its
        // own.
        let mut nl = Netlist::new();
        let wdata: Vec<_> = (0..8).map(|_| nl.net()).collect();
        let waddr: Vec<_> = (0..4).map(|_| nl.net()).collect();
        let we = nl.net();
        let raddr: Vec<_> = (0..4).map(|_| nl.net()).collect();
        let rdata: Vec<_> = (0..8).map(|_| nl.net()).collect();
        for (name, bus) in [("wdata", &wdata), ("waddr", &waddr), ("raddr", &raddr)] {
            for &n in bus.iter() {
                nl.add_cell(CellKind::Input { name: name.into() }, vec![], vec![n]);
            }
            nl.inputs.push((name.into(), bus.to_vec()));
        }
        nl.add_cell(CellKind::Input { name: "we".into() }, vec![], vec![we]);
        nl.inputs.push(("we".into(), vec![we]));
        let mut ins = wdata.clone();
        ins.extend(&waddr);
        ins.push(we);
        ins.extend(&raddr);
        nl.add_cell(CellKind::Ramb18 { width: 8, depth: 16 }, ins, rdata.clone());
        nl.outputs.push(("rdata".into(), rdata));
        let lanes = 8;
        let mut sim = Sim::with_lanes(&nl, lanes).unwrap();
        let wd_ix = sim.input_index("wdata");
        let wa_ix = sim.input_index("waddr");
        let ra_ix = sim.input_index("raddr");
        for lane in 0..lanes {
            sim.set_input_lane_at(wd_ix, lane, 0x30 + lane as u64);
            sim.set_input_lane_at(wa_ix, lane, lane as u64);
            sim.set_input_lane_at(ra_ix, lane, lane as u64);
        }
        sim.set_input("we", 1);
        sim.settle();
        sim.tick();
        sim.set_input("we", 0);
        sim.settle();
        sim.tick();
        let out_ix = sim.output_index("rdata");
        for lane in 0..lanes {
            assert_eq!(sim.output_unsigned_lane_at(out_ix, lane), 0x30 + lane as u64, "lane {lane}");
        }
    }

    // ---------------- wide-bus regression (>64-bit ports) ----------------

    /// A 72-bit pass-through bus: in -> register -> out.
    fn wide_bus_nl() -> Netlist {
        let mut nl = Netlist::new();
        let mut b = Builder::new(&mut nl);
        let x = b.input("x", 72);
        let ce = b.one();
        let r = b.zero();
        let q = b.register(&x, ce, r);
        b.output("q", &q);
        nl
    }

    #[test]
    fn wide_bus_roundtrips_through_field_accessors() {
        let nl = wide_bus_nl();
        let mut sim = Sim::new(&nl).unwrap();
        let x_ix = sim.input_index("x");
        // Pack 9 bytes, read them back through 8-bit output slices.
        for e in 0..9 {
            sim.set_input_field_at(x_ix, e * 8, 8, 0xA0 + e as u64);
        }
        sim.settle();
        sim.tick();
        for e in 0..9 {
            let bus: Vec<_> = nl.outputs[0].1[e * 8..(e + 1) * 8].to_vec();
            assert_eq!(sim.get_unsigned(&bus), 0xA0 + e as u64, "byte {e}");
        }
    }

    #[test]
    #[should_panic(expected = "72 bits wide")]
    fn wide_bus_whole_set_panics_instead_of_wrapping() {
        let nl = wide_bus_nl();
        let mut sim = Sim::new(&nl).unwrap();
        // Silently wrapped the shift (or debug-panicked deep in the loop)
        // before; now a clear width assert fires at the API boundary.
        sim.set_input("x", 1);
    }

    #[test]
    #[should_panic(expected = "72 bits wide")]
    fn wide_bus_whole_get_panics_instead_of_wrapping() {
        let nl = wide_bus_nl();
        let sim = Sim::new(&nl).unwrap();
        let _ = sim.output_unsigned("q");
    }

    #[test]
    fn non_power_of_two_ram_depth_simulates() {
        // depth 12 -> 4 address bits via ram_addr_bits; a sim over it
        // must construct and round-trip (regression for the float
        // log2().ceil() duplication).
        let mut nl = Netlist::new();
        let wdata: Vec<_> = (0..4).map(|_| nl.net()).collect();
        let waddr: Vec<_> = (0..4).map(|_| nl.net()).collect();
        let we = nl.net();
        let raddr: Vec<_> = (0..4).map(|_| nl.net()).collect();
        let rdata: Vec<_> = (0..4).map(|_| nl.net()).collect();
        for (name, bus) in [("wdata", &wdata), ("waddr", &waddr), ("raddr", &raddr)] {
            for &n in bus.iter() {
                nl.add_cell(CellKind::Input { name: name.into() }, vec![], vec![n]);
            }
            nl.inputs.push((name.into(), bus.to_vec()));
        }
        nl.add_cell(CellKind::Input { name: "we".into() }, vec![], vec![we]);
        nl.inputs.push(("we".into(), vec![we]));
        let mut ins = wdata.clone();
        ins.extend(&waddr);
        ins.push(we);
        ins.extend(&raddr);
        nl.add_cell(CellKind::Ramb18 { width: 4, depth: 12 }, ins, rdata.clone());
        nl.outputs.push(("rdata".into(), rdata));
        let mut sim = Sim::new(&nl).unwrap();
        sim.set_input("wdata", 0x9);
        sim.set_input("waddr", 11);
        sim.set_input("raddr", 11);
        sim.set_input("we", 1);
        sim.settle();
        sim.tick();
        sim.set_input("we", 0);
        sim.settle();
        sim.tick();
        assert_eq!(sim.output_unsigned("rdata"), 0x9);
    }

    #[test]
    fn xor_reg_full_occupancy_differential() {
        // All 64 lanes carry distinct streams; spot-check the smallest
        // sequential netlist at maximum width.
        let nl = xor_reg();
        let mut lane_sim = Sim::with_lanes(&nl, LANES).unwrap();
        let mut scalars: Vec<Sim> = (0..LANES).map(|_| Sim::new(&nl).unwrap()).collect();
        let mut rng = Rng::new(3);
        let streams: Vec<Vec<(u64, u64)>> = (0..LANES)
            .map(|_| (0..8).map(|_| (rng.below(2), rng.below(2))).collect())
            .collect();
        let a_ix = lane_sim.input_index("a");
        let b_ix = lane_sim.input_index("b");
        for t in 0..8 {
            for (lane, s) in streams.iter().enumerate() {
                lane_sim.set_input_lane_at(a_ix, lane, s[t].0);
                lane_sim.set_input_lane_at(b_ix, lane, s[t].1);
                scalars[lane].set_input("a", s[t].0);
                scalars[lane].set_input("b", s[t].1);
            }
            lane_sim.settle();
            lane_sim.tick();
            for sc in scalars.iter_mut() {
                sc.settle();
                sc.tick();
            }
            let q_ix = lane_sim.output_index("q");
            for (lane, sc) in scalars.iter().enumerate() {
                assert_eq!(
                    lane_sim.output_unsigned_lane_at(q_ix, lane),
                    sc.output_unsigned("q"),
                    "t={t} lane={lane}"
                );
            }
        }
        let scalar_total: u64 = scalars.iter().map(|s| s.toggle_total()).sum();
        assert_eq!(lane_sim.toggle_total(), scalar_total);
    }

    // ---------------- event-driven scheduler coverage ----------------

    /// `chains` independent NOT-LUT chains of length `len`: input "x{i}"
    /// feeds a chain whose final net is output "y{i}". Wide enough that
    /// a single-input poke stays under the dense-fallback threshold, so
    /// these tests pin the *event* path specifically (tiny netlists
    /// always fall back to the dense sweep).
    fn not_chains(chains: usize, len: usize) -> Netlist {
        let mut nl = Netlist::new();
        for i in 0..chains {
            let x = nl.net();
            nl.add_cell(CellKind::Input { name: format!("x{i}") }, vec![], vec![x]);
            nl.inputs.push((format!("x{i}"), vec![x]));
            let mut prev = x;
            for _ in 0..len {
                let o = nl.net();
                nl.add_cell(CellKind::Lut { funcs: vec![Lut::not1()] }, vec![prev], vec![o]);
                prev = o;
            }
            nl.outputs.push((format!("y{i}"), vec![prev]));
        }
        nl
    }

    #[test]
    fn event_settle_wakes_only_the_touched_cone() {
        // 16 chains x 2 NOTs = 32 ops; poking one input must evaluate
        // exactly that chain's 2 ops and nothing else.
        let nl = not_chains(16, 2);
        let mut sim = Sim::new(&nl).unwrap();
        {
            let st = sim.settle_stats();
            assert_eq!(st.settles, 1, "construction runs the bootstrap settle");
            assert_eq!(st.dense_settles, 1);
            assert_eq!(st.ops_evaluated, 32);
            assert_eq!(st.ops_total, 32);
        }
        sim.set_input("x0", 1);
        sim.settle();
        let st = sim.settle_stats().clone();
        assert_eq!(st.settles, 2);
        assert_eq!(st.dense_settles, 1, "poke settle must take the event path");
        assert_eq!(st.event_settles(), 1);
        assert_eq!(st.ops_evaluated, 34, "only the 2-op cone of x0 re-evaluates");
        assert_eq!(st.ops_total, 64);
        assert_eq!(st.wakeups_per_level, vec![0, 1, 1]);
        assert!(st.evaluated_fraction() < 1.0);
        // Values are still exact: y0 follows x0, every other chain holds.
        assert_eq!(sim.output_unsigned("y0"), 1);
        for i in 1..16 {
            assert_eq!(sim.output_unsigned(&format!("y{i}")), 0, "chain {i} untouched");
        }
        sim.assert_dense_fixpoint();
    }

    #[test]
    fn redundant_stimulus_costs_no_settle_work() {
        // Satellite regression: setters wake only when the lane word
        // actually changes, so repeated identical stimulus evaluates
        // nothing.
        let nl = not_chains(16, 2);
        let mut sim = Sim::new(&nl).unwrap();
        let baseline = sim.settle_stats().ops_evaluated;
        let x0 = sim.input_index("x0");
        sim.set_input_at(x0, 0); // already 0 everywhere
        sim.set_input_lane_at(x0, 0, 0); // already 0 in lane 0
        sim.settle();
        let st = sim.settle_stats().clone();
        assert_eq!(st.ops_evaluated, baseline, "identical stimulus woke ops");
        assert_eq!(st.event_settles(), 1, "empty settle still takes the event path");
        // A real change must still wake the cone (the setter is not
        // silently dropping work).
        sim.set_input_at(x0, 1);
        sim.settle();
        assert_eq!(sim.settle_stats().ops_evaluated, baseline + 2);
        assert_eq!(sim.output_unsigned("y0"), 1);
    }

    /// Differential property: the event-driven scheduler must match a
    /// forced dense sweep cycle for cycle — bit-exact outputs AND exact
    /// toggle totals — at 1/8/64 lanes.
    #[test]
    fn prop_event_settle_matches_dense_sweep() {
        forall("event settle == forced dense sweep", 25, |g| {
            let wa = g.usize_in(2, 8);
            let wb = g.usize_in(2, 8);
            let sub = g.bool();
            let cut = g.bool();
            let lanes = [1usize, 8, LANES][g.usize_in(0, 2)];
            let cycles = g.usize_in(2, 6);
            let nl = random_arith(wa, wb, sub, cut);
            let amask = (1u64 << wa) - 1;
            let bmask = (1u64 << wb) - 1;
            let mut ev = Sim::with_lanes(&nl, lanes).unwrap();
            let mut dn = Sim::with_lanes(&nl, lanes).unwrap();
            dn.set_force_dense(true);
            let outs = ["s", "p", "q"];
            for t in 0..cycles {
                for lane in 0..lanes {
                    let av = (g.signed_bits(wa as u32) as u64) & amask;
                    let bv = (g.signed_bits(wb as u32) as u64) & bmask;
                    ev.set_input_lane("a", lane, av);
                    ev.set_input_lane("b", lane, bv);
                    dn.set_input_lane("a", lane, av);
                    dn.set_input_lane("b", lane, bv);
                }
                // Settle twice: the second pass re-settles an already
                // settled state (free on the event side) and must agree.
                for _ in 0..2 {
                    ev.settle();
                    dn.settle();
                }
                for name in outs {
                    let ox = ev.output_index(name);
                    for lane in 0..lanes {
                        let got = ev.output_signed_lane_at(ox, lane);
                        let want = dn.output_signed_lane_at(ox, lane);
                        if got != want {
                            return Err(format!(
                                "wa={wa} wb={wb} sub={sub} cut={cut} lanes={lanes} t={t} lane={lane} {name}: event {got} != dense {want}"
                            ));
                        }
                    }
                }
                ev.tick();
                dn.tick();
            }
            if ev.toggle_total() != dn.toggle_total() {
                return Err(format!(
                    "toggle totals diverge: event={} dense={}",
                    ev.toggle_total(),
                    dn.toggle_total()
                ));
            }
            ev.assert_dense_fixpoint();
            let st = ev.settle_stats();
            if st.ops_evaluated > st.ops_total {
                return Err(format!("stats bound violated: {st:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn settle_stats_monotone_and_bounded() {
        let nl = random_arith(6, 6, false, true);
        let mut sim = Sim::with_lanes(&nl, 8).unwrap();
        let mut rng = Rng::new(7);
        let mut prev = sim.settle_stats().clone();
        for _ in 0..20 {
            sim.set_input("a", rng.below(1 << 6));
            sim.set_input("b", rng.below(1 << 6));
            sim.settle();
            sim.tick();
            let st = sim.settle_stats().clone();
            assert!(st.settles > prev.settles, "settles not monotone");
            assert!(st.ops_evaluated >= prev.ops_evaluated, "ops_evaluated not monotone");
            assert!(st.ops_total >= prev.ops_total, "ops_total not monotone");
            assert!(st.ops_evaluated <= st.ops_total, "evaluated exceeds dense workload");
            assert!(st.dense_settles <= st.settles);
            // Every wakeup is an evaluation in some event settle.
            let wakeups: u64 = st.wakeups_per_level.iter().sum();
            assert!(wakeups <= st.ops_evaluated);
            prev = st;
        }
        assert!(sim.settle_stats().evaluated_fraction() <= 1.0);
    }

    #[test]
    fn settle_stats_deltas_partition_the_cumulative_totals() {
        // The interval-attribution contract: snapshot before each settle,
        // take delta_since after, and the per-settle deltas must sum back
        // to the cumulative counters exactly — no double-counting across
        // consecutive settles, including the per-level wakeup histogram.
        let nl = random_arith(6, 6, false, true);
        let mut sim = Sim::with_lanes(&nl, 8).unwrap();
        let mut rng = Rng::new(21);
        // Seed the accumulator with the construction-time bootstrap
        // settle, which happened before the first interval snapshot.
        let mut acc = sim.settle_stats().clone();
        for _ in 0..12 {
            let before = sim.settle_stats().clone();
            sim.set_input("a", rng.below(1 << 6));
            sim.set_input("b", rng.below(1 << 6));
            sim.settle();
            sim.tick();
            let d = sim.settle_stats().delta_since(&before);
            // The explicit settle plus the re-settle inside tick().
            assert_eq!(d.settles, 2, "each iteration contributes exactly two settles");
            assert!(d.ops_evaluated <= d.ops_total);
            acc.settles += d.settles;
            acc.dense_settles += d.dense_settles;
            acc.ops_evaluated += d.ops_evaluated;
            acc.ops_total += d.ops_total;
            for (a, w) in acc.wakeups_per_level.iter_mut().zip(&d.wakeups_per_level) {
                *a += w;
            }
        }
        let total = sim.settle_stats();
        assert_eq!(acc.settles, total.settles);
        assert_eq!(acc.dense_settles, total.dense_settles);
        assert_eq!(acc.ops_evaluated, total.ops_evaluated);
        assert_eq!(acc.ops_total, total.ops_total);
        assert_eq!(acc.wakeups_per_level, total.wakeups_per_level);
        // delta_since(self) is zero; a stale (larger) baseline saturates.
        let z = total.delta_since(total);
        assert_eq!((z.settles, z.ops_evaluated, z.ops_total), (0, 0, 0));
        assert!(z.wakeups_per_level.iter().all(|&w| w == 0));
        let stale = total.delta_since(&SettleStats {
            settles: total.settles + 5,
            ..total.clone()
        });
        assert_eq!(stale.settles, 0);
        // reset zeroes counters but keeps the histogram's length.
        let mut r = total.clone();
        r.reset();
        assert_eq!((r.settles, r.dense_settles, r.ops_evaluated, r.ops_total), (0, 0, 0, 0));
        assert_eq!(r.wakeups_per_level.len(), total.wakeups_per_level.len());
        assert!(r.wakeups_per_level.iter().all(|&w| w == 0));
    }

    #[test]
    fn dense_fixpoint_holds_after_event_settles() {
        // Random single-lane pokes on a 64-op netlist: every settle must
        // leave a state the dense sweep would not change, and at least
        // some settles must actually skip work.
        let nl = not_chains(16, 4);
        let mut sim = Sim::with_lanes(&nl, LANES).unwrap();
        let mut rng = Rng::new(9);
        for t in 0..32usize {
            let i = rng.below(16) as usize;
            sim.set_input_lane(&format!("x{i}"), t % LANES, rng.below(2));
            sim.settle();
            sim.assert_dense_fixpoint();
        }
        let st = sim.settle_stats();
        assert!(st.event_settles() >= 1, "no event-path settles ran: {st:?}");
        assert!(st.ops_evaluated < st.ops_total, "no work was skipped: {st:?}");
    }

    #[test]
    fn force_dense_release_rebootstraps() {
        let nl = not_chains(4, 2);
        let mut sim = Sim::new(&nl).unwrap();
        sim.set_force_dense(true);
        sim.set_input("x0", 1);
        sim.settle(); // dense, wakeups suppressed
        assert_eq!(sim.output_unsigned("y0"), 1);
        sim.set_force_dense(false);
        sim.set_input("x1", 1);
        sim.settle(); // must re-bootstrap densely — and still be exact
        assert_eq!(sim.output_unsigned("y1"), 1);
        sim.assert_dense_fixpoint();
        let st = sim.settle_stats();
        assert_eq!(st.dense_settles, st.settles, "post-release settle must be dense");
    }
}
