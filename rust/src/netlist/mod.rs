//! Structural netlists over fabric primitives.
//!
//! A [`Netlist`] is a graph of single-bit nets and primitive cells — the
//! same abstraction level as a post-synthesis Vivado netlist, which is
//! what the paper's hand-written structural VHDL effectively pins down.
//! The IP generators in [`crate::ips`] build netlists through the
//! [`builder::Builder`] DSL; [`sim::Sim`] evaluates them bit-exactly;
//! [`crate::synth`] counts them into Table II rows; [`crate::sta`] walks
//! them for WNS.
//!
//! Conventions:
//! * Nets are 1-bit. Multi-bit values are [`builder::Bus`]es (LSB-first
//!   vectors of nets). Sign extension replicates the MSB net — free, as on
//!   hardware.
//! * A LUT cell may carry two functions of ≤5 shared inputs (the LUT6_2
//!   O5/O6 fracture) and still counts as one LUT — this matters for
//!   matching realistic multiplier costs.
//! * Sequential cells (FDRE, DSP48E2, RAMB18) break combinational paths;
//!   one implicit global clock.

pub mod builder;
pub mod opt;
pub mod sim;

use crate::fabric::dsp48;
use crate::fabric::lut::Lut;
use crate::fabric::Prim;

/// Exact address width of a `depth`-entry memory: `ceil(log2(depth))`
/// bits (0 for depth 1). Shared by [`Netlist::check`]'s arity rules and
/// [`sim::Sim`]'s RAM decode so the two can never disagree — the float
/// `log2().ceil()` they previously duplicated is replaced by integer
/// arithmetic.
pub fn ram_addr_bits(depth: u32) -> usize {
    crate::fixed::ceil_log2(depth) as usize
}

/// Net index.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct NetId(pub u32);

/// Cell index.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct CellId(pub u32);

/// Primitive cell kinds.
#[derive(Debug, Clone)]
pub enum CellKind {
    /// Function generator: up to two functions over shared inputs.
    /// `funcs.len() == 1` → plain LUT; `== 2` → fractured LUT6_2 (≤5 ins).
    Lut { funcs: Vec<Lut> },
    /// D flip-flop. Pins in: `[D, CE, R]`; out: `[Q]`.
    Fdre,
    /// Carry chain. Pins in: `[S0..S7, DI0..DI7, CI]`; out: `[O0..O7, CO0..CO7]`.
    Carry8,
    /// DSP slice. Pins in: `[A(27), B(18), C(48), D(27), ZMUX(2), CE]`;
    /// out: `[P(48)]`. ZMUX encoding: 00=Zero, 01=P, 10=C.
    Dsp48e2 { cfg: dsp48::Config },
    /// Block RAM, simple dual port, registered read.
    /// Pins in: `[WDATA(w), WADDR(log2 d), WE, RADDR(log2 d)]`; out: `[RDATA(w)]`.
    Ramb18 { width: u32, depth: u32 },
    /// Constant driver. Out: `[Q]`.
    Const { value: bool },
    /// Primary input bit. Out: `[Q]`.
    Input { name: String },
}

impl CellKind {
    /// Which census bucket does this cell land in (None for virtual cells).
    pub fn prim(&self) -> Option<Prim> {
        match self {
            CellKind::Lut { .. } => Some(Prim::Lut),
            CellKind::Fdre => Some(Prim::Ff),
            CellKind::Carry8 => Some(Prim::Carry8),
            CellKind::Dsp48e2 { .. } => Some(Prim::Dsp48e2),
            CellKind::Ramb18 { .. } => Some(Prim::Ramb18),
            CellKind::Const { .. } | CellKind::Input { .. } => None,
        }
    }

    /// Sequential cells latch on the clock edge and cut timing paths.
    pub fn is_sequential(&self) -> bool {
        matches!(self, CellKind::Fdre | CellKind::Dsp48e2 { .. } | CellKind::Ramb18 { .. })
    }
}

/// One cell instance.
#[derive(Debug, Clone)]
pub struct Cell {
    pub kind: CellKind,
    pub ins: Vec<NetId>,
    pub outs: Vec<NetId>,
}

/// The netlist graph.
#[derive(Debug, Clone, Default)]
pub struct Netlist {
    pub cells: Vec<Cell>,
    /// Driver of each net (cell, output-pin index). Primary inputs and
    /// constants are driven by their virtual cells.
    drivers: Vec<Option<(CellId, u16)>>,
    /// Declared top-level outputs: (name, bus of nets).
    pub outputs: Vec<(String, Vec<NetId>)>,
    /// Declared top-level inputs: (name, bus of nets) in declaration order.
    pub inputs: Vec<(String, Vec<NetId>)>,
}

#[derive(Debug)]
pub enum NetlistError {
    Undriven(NetId),
    MultipleDrivers(NetId),
    CombLoop(CellId),
    Arity(CellId, String),
}

impl std::fmt::Display for NetlistError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            NetlistError::Undriven(n) => write!(f, "net {n:?} has no driver"),
            NetlistError::MultipleDrivers(n) => write!(f, "net {n:?} has multiple drivers"),
            NetlistError::CombLoop(c) => write!(f, "combinational loop through cell {c:?}"),
            NetlistError::Arity(c, what) => write!(f, "pin arity mismatch on cell {c:?}: {what}"),
        }
    }
}

impl std::error::Error for NetlistError {}

impl Netlist {
    pub fn new() -> Self {
        Netlist::default()
    }

    pub fn n_nets(&self) -> usize {
        self.drivers.len()
    }

    pub fn n_cells(&self) -> usize {
        self.cells.len()
    }

    /// Allocate a fresh undriven net.
    pub fn net(&mut self) -> NetId {
        let id = NetId(self.drivers.len() as u32);
        self.drivers.push(None);
        id
    }

    /// Add a cell; registers it as driver of its output nets.
    pub fn add_cell(&mut self, kind: CellKind, ins: Vec<NetId>, outs: Vec<NetId>) -> CellId {
        let id = CellId(self.cells.len() as u32);
        for (pin, &o) in outs.iter().enumerate() {
            let slot = &mut self.drivers[o.0 as usize];
            assert!(slot.is_none(), "net {o:?} already driven");
            *slot = Some((id, pin as u16));
        }
        self.cells.push(Cell { kind, ins, outs });
        id
    }

    pub fn driver(&self, n: NetId) -> Option<(CellId, u16)> {
        self.drivers[n.0 as usize]
    }

    pub fn cell(&self, c: CellId) -> &Cell {
        &self.cells[c.0 as usize]
    }

    /// Census: count cells per primitive kind.
    pub fn census(&self) -> std::collections::BTreeMap<Prim, u64> {
        let mut m = std::collections::BTreeMap::new();
        for c in &self.cells {
            if let Some(p) = c.kind.prim() {
                *m.entry(p).or_insert(0) += 1;
            }
        }
        m
    }

    /// Fanout count per net (used by STA's routing-delay estimate).
    pub fn fanouts(&self) -> Vec<u32> {
        let mut f = vec![0u32; self.n_nets()];
        for c in &self.cells {
            for &i in &c.ins {
                f[i.0 as usize] += 1;
            }
        }
        for (_, bus) in &self.outputs {
            for &n in bus {
                f[n.0 as usize] += 1;
            }
        }
        f
    }

    /// Validate: every net driven exactly once, pin arities consistent,
    /// and no combinational loops. Returns the combinational topological
    /// order (cell indices, sequential cells excluded).
    pub fn check(&self) -> Result<Vec<CellId>, NetlistError> {
        for (i, d) in self.drivers.iter().enumerate() {
            if d.is_none() {
                return Err(NetlistError::Undriven(NetId(i as u32)));
            }
        }
        for (ci, c) in self.cells.iter().enumerate() {
            let id = CellId(ci as u32);
            let (want_in, want_out): (usize, usize) = match &c.kind {
                CellKind::Lut { funcs } => {
                    let k = funcs[0].k as usize;
                    if funcs.len() == 2 {
                        if k > 5 {
                            return Err(NetlistError::Arity(id, "dual LUT needs k<=5".into()));
                        }
                        if funcs[1].k != funcs[0].k {
                            return Err(NetlistError::Arity(id, "dual LUT arity mismatch".into()));
                        }
                    }
                    (k, funcs.len())
                }
                CellKind::Fdre => (3, 1),
                CellKind::Carry8 => (17, 16),
                CellKind::Dsp48e2 { .. } => (27 + 18 + 48 + 27 + 2 + 1, 48),
                CellKind::Ramb18 { width, depth } => {
                    let ab = ram_addr_bits(*depth);
                    ((*width as usize) + ab + 1 + ab, *width as usize)
                }
                CellKind::Const { .. } => (0, 1),
                CellKind::Input { .. } => (0, 1),
            };
            if c.ins.len() != want_in || c.outs.len() != want_out {
                return Err(NetlistError::Arity(
                    id,
                    format!("got {}in/{}out want {want_in}in/{want_out}out", c.ins.len(), c.outs.len()),
                ));
            }
        }
        self.topo_comb()
    }

    /// Driven-but-unread nets that make their driver wholly
    /// unobservable: every output of the (non-`Input`) driver cell has
    /// zero readers and is not a declared output, so the cell is
    /// silently simulated for nothing. Partially-used fixed-arity
    /// primitives (CARRY8 carry-outs, spare DSP product bits) are *not*
    /// flagged — their cells still feed live pins. [`opt::dce::Dce`]
    /// removes every flagged net; see [`Netlist::check_warn`].
    pub fn unread_nets(&self) -> Vec<NetId> {
        let fan = self.fanouts();
        let mut bad = Vec::new();
        for c in &self.cells {
            if matches!(c.kind, CellKind::Input { .. }) {
                continue;
            }
            if c.outs.iter().all(|&o| fan[o.0 as usize] == 0) {
                bad.extend(c.outs.iter().copied());
            }
        }
        bad
    }

    /// [`Netlist::check`] plus the builder-wart warning list: the
    /// combinational order and the [`Netlist::unread_nets`] to warn
    /// about (empty on any netlist that went through dead-logic
    /// elimination).
    pub fn check_warn(&self) -> Result<(Vec<CellId>, Vec<NetId>), NetlistError> {
        let order = self.check()?;
        Ok((order, self.unread_nets()))
    }

    /// Topological level of every cell, computed from a combinational
    /// order produced by [`Netlist::check`]/[`Netlist::topo_comb`].
    /// Sources — primary inputs, constants, and (by convention)
    /// sequential cells, whose outputs the settle pass treats as
    /// sources — sit at level 0; every other combinational cell is one
    /// more than its deepest combinational driver. The contract the
    /// event-driven simulator schedules by: for every comb→comb edge,
    /// `level(consumer) > level(producer)`, so one ascending sweep over
    /// per-level dirty queues reaches the settle fixpoint with each
    /// woken cell evaluated exactly once.
    pub fn comb_levels(&self, order: &[CellId]) -> Vec<u32> {
        let mut level = vec![0u32; self.cells.len()];
        for &cid in order {
            let c = self.cell(cid);
            let mut l = 0u32;
            for &i in &c.ins {
                if let Some((d, _)) = self.drivers[i.0 as usize] {
                    if !self.cells[d.0 as usize].kind.is_sequential() {
                        l = l.max(level[d.0 as usize] + 1);
                    }
                }
            }
            level[cid.0 as usize] = l;
        }
        level
    }

    /// Topological order over combinational cells (Kahn). Sequential cell
    /// outputs are treated as sources.
    pub fn topo_comb(&self) -> Result<Vec<CellId>, NetlistError> {
        let n = self.cells.len();
        let mut indeg = vec![0u32; n];
        // For each combinational cell, count inputs driven by combinational cells.
        let mut users: Vec<Vec<u32>> = vec![Vec::new(); n]; // comb cell -> comb users
        for (ci, c) in self.cells.iter().enumerate() {
            if c.kind.is_sequential() {
                continue;
            }
            for &i in &c.ins {
                if let Some((d, _)) = self.drivers[i.0 as usize] {
                    if !self.cells[d.0 as usize].kind.is_sequential() {
                        indeg[ci] += 1;
                        users[d.0 as usize].push(ci as u32);
                    }
                }
            }
        }
        let mut q: Vec<u32> = (0..n as u32)
            .filter(|&i| !self.cells[i as usize].kind.is_sequential() && indeg[i as usize] == 0)
            .collect();
        let mut order = Vec::with_capacity(n);
        while let Some(c) = q.pop() {
            order.push(CellId(c));
            for &u in &users[c as usize] {
                indeg[u as usize] -= 1;
                if indeg[u as usize] == 0 {
                    q.push(u);
                }
            }
        }
        let comb_total = self.cells.iter().filter(|c| !c.kind.is_sequential()).count();
        if order.len() != comb_total {
            // Find a cell still with indegree > 0 for the error message.
            let stuck = indeg
                .iter()
                .enumerate()
                .find(|(i, &d)| d > 0 && !self.cells[*i].kind.is_sequential())
                .map(|(i, _)| CellId(i as u32))
                .unwrap_or(CellId(0));
            return Err(NetlistError::CombLoop(stuck));
        }
        Ok(order)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::lut::Lut;

    fn tiny() -> (Netlist, NetId, NetId, NetId) {
        let mut nl = Netlist::new();
        let a = nl.net();
        let b = nl.net();
        let y = nl.net();
        nl.add_cell(CellKind::Input { name: "a".into() }, vec![], vec![a]);
        nl.add_cell(CellKind::Input { name: "b".into() }, vec![], vec![b]);
        nl.add_cell(CellKind::Lut { funcs: vec![Lut::xor2()] }, vec![a, b], vec![y]);
        nl.inputs.push(("a".into(), vec![a]));
        nl.inputs.push(("b".into(), vec![b]));
        nl.outputs.push(("y".into(), vec![y]));
        (nl, a, b, y)
    }

    #[test]
    fn check_passes_on_tiny() {
        let (nl, ..) = tiny();
        let order = nl.check().unwrap();
        assert_eq!(order.len(), 3); // 2 inputs + 1 lut
    }

    #[test]
    fn census_counts_luts() {
        let (nl, ..) = tiny();
        let c = nl.census();
        assert_eq!(c.get(&Prim::Lut), Some(&1));
        assert_eq!(c.get(&Prim::Ff), None);
    }

    #[test]
    fn undriven_detected() {
        let mut nl = Netlist::new();
        let a = nl.net();
        let y = nl.net();
        nl.add_cell(CellKind::Lut { funcs: vec![Lut::not1()] }, vec![a], vec![y]);
        assert!(matches!(nl.check(), Err(NetlistError::Undriven(_))));
    }

    #[test]
    #[should_panic(expected = "already driven")]
    fn double_driver_panics() {
        let mut nl = Netlist::new();
        let y = nl.net();
        nl.add_cell(CellKind::Const { value: true }, vec![], vec![y]);
        nl.add_cell(CellKind::Const { value: false }, vec![], vec![y]);
    }

    #[test]
    fn comb_loop_detected() {
        let mut nl = Netlist::new();
        let a = nl.net();
        let b = nl.net();
        nl.add_cell(CellKind::Lut { funcs: vec![Lut::not1()] }, vec![b], vec![a]);
        nl.add_cell(CellKind::Lut { funcs: vec![Lut::not1()] }, vec![a], vec![b]);
        assert!(matches!(nl.check(), Err(NetlistError::CombLoop(_))));
    }

    #[test]
    fn ff_breaks_loop() {
        let mut nl = Netlist::new();
        let q = nl.net();
        let d = nl.net();
        let ce = nl.net();
        let r = nl.net();
        nl.add_cell(CellKind::Const { value: true }, vec![], vec![ce]);
        nl.add_cell(CellKind::Const { value: false }, vec![], vec![r]);
        nl.add_cell(CellKind::Lut { funcs: vec![Lut::not1()] }, vec![q], vec![d]);
        nl.add_cell(CellKind::Fdre, vec![d, ce, r], vec![q]);
        assert!(nl.check().is_ok(), "FF must break the cycle");
    }

    #[test]
    fn fanouts_counted() {
        let (mut nl, a, _b, y) = tiny();
        let z = nl.net();
        nl.add_cell(CellKind::Lut { funcs: vec![Lut::not1()] }, vec![a], vec![z]);
        nl.outputs.push(("z".into(), vec![z]));
        let f = nl.fanouts();
        assert_eq!(f[a.0 as usize], 2); // xor + not
        assert_eq!(f[y.0 as usize], 1); // top output
    }

    #[test]
    fn ram_addr_bits_exact_on_any_depth() {
        // Non-power-of-two depths are the interesting cases: the address
        // width must cover depth-1 without wasting a bit.
        for (depth, want) in [(1u32, 0usize), (2, 1), (3, 2), (5, 3), (9, 4), (12, 4), (1000, 10), (4096, 12), (4097, 13)] {
            assert_eq!(ram_addr_bits(depth), want, "depth {depth}");
        }
        for depth in 1u32..=4100 {
            let bits = ram_addr_bits(depth);
            assert!((1u64 << bits) >= depth as u64, "depth {depth}: {bits} bits too narrow");
            assert!(bits == 0 || (1u64 << (bits - 1)) < depth as u64, "depth {depth}: {bits} bits wasteful");
        }
    }

    #[test]
    fn comb_levels_count_chain_depth() {
        // a -> not -> not -> not: the chain levels 1, 2, 3 above the input.
        let mut nl = Netlist::new();
        let a = nl.net();
        let x = nl.net();
        let y = nl.net();
        let z = nl.net();
        nl.add_cell(CellKind::Input { name: "a".into() }, vec![], vec![a]);
        let c1 = nl.add_cell(CellKind::Lut { funcs: vec![Lut::not1()] }, vec![a], vec![x]);
        let c2 = nl.add_cell(CellKind::Lut { funcs: vec![Lut::not1()] }, vec![x], vec![y]);
        let c3 = nl.add_cell(CellKind::Lut { funcs: vec![Lut::not1()] }, vec![y], vec![z]);
        nl.inputs.push(("a".into(), vec![a]));
        nl.outputs.push(("z".into(), vec![z]));
        let order = nl.check().unwrap();
        let levels = nl.comb_levels(&order);
        assert_eq!(levels[0], 0, "input cell is a source");
        assert_eq!(levels[c1.0 as usize], 1);
        assert_eq!(levels[c2.0 as usize], 2);
        assert_eq!(levels[c3.0 as usize], 3);
    }

    #[test]
    fn comb_levels_strictly_increase_along_comb_edges_of_real_ip() {
        // The schedule contract on a real generated netlist: every
        // combinational consumer sits strictly above each of its
        // combinational producers, and sequential cells cut the order.
        let p = crate::ips::ConvParams::paper_8bit();
        let ip = crate::ips::generate(crate::ips::ConvKind::Conv1, &p).unwrap();
        let order = ip.netlist.check().unwrap();
        let levels = ip.netlist.comb_levels(&order);
        let mut max_level = 0;
        for (ci, c) in ip.netlist.cells.iter().enumerate() {
            if c.kind.is_sequential() {
                continue;
            }
            let mut want = 0u32;
            for &i in &c.ins {
                let (d, _) = ip.netlist.driver(i).unwrap();
                if !ip.netlist.cell(d).kind.is_sequential() {
                    assert!(
                        levels[ci] > levels[d.0 as usize],
                        "cell {ci}: consumer level {} <= producer level {}",
                        levels[ci],
                        levels[d.0 as usize]
                    );
                    want = want.max(levels[d.0 as usize] + 1);
                }
            }
            assert_eq!(levels[ci], want, "cell {ci} level not tight");
            max_level = max_level.max(levels[ci]);
        }
        assert!(max_level >= 4, "Conv_1 should levelize non-trivially, got {max_level}");
    }

    #[test]
    fn arity_mismatch_detected() {
        let mut nl = Netlist::new();
        let a = nl.net();
        let y = nl.net();
        nl.add_cell(CellKind::Input { name: "a".into() }, vec![], vec![a]);
        nl.add_cell(CellKind::Lut { funcs: vec![Lut::xor2()] }, vec![a], vec![y]); // xor2 wants 2 ins
        assert!(matches!(nl.check(), Err(NetlistError::Arity(..))));
    }
}
