//! Bus-level construction DSL over [`Netlist`] — the Rust equivalent of
//! the paper's structural VHDL.
//!
//! Everything decomposes to real fabric primitives with realistic costs:
//! adders are fused-LUT + CARRY8 ripple chains (one LUT per bit), the
//! signed array multiplier uses dual-output LUT3 rows (one LUT per bit per
//! row — the mapping Vivado produces for `a*b` on logic), registers are
//! FDRE vectors. Sign extension replicates the MSB *net* and costs
//! nothing, exactly as on hardware.

use super::{CellKind, NetId, Netlist};
use crate::fabric::carry::CARRY8_WIDTH;
use crate::fabric::dsp48;
use crate::fabric::lut::Lut;

/// A multi-bit signal: LSB-first vector of nets, interpreted as two's
/// complement by the arithmetic helpers.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Bus(pub Vec<NetId>);

impl Bus {
    pub fn width(&self) -> usize {
        self.0.len()
    }

    pub fn msb(&self) -> NetId {
        *self.0.last().expect("empty bus")
    }

    pub fn bit(&self, i: usize) -> NetId {
        self.0[i]
    }

    /// Bits `[lo, hi)` as a new bus (shares nets).
    pub fn slice(&self, lo: usize, hi: usize) -> Bus {
        Bus(self.0[lo..hi].to_vec())
    }

    pub fn nets(&self) -> &[NetId] {
        &self.0
    }
}

/// Builder over a netlist.
pub struct Builder<'a> {
    pub nl: &'a mut Netlist,
    zero: Option<NetId>,
    one: Option<NetId>,
}

impl<'a> Builder<'a> {
    pub fn new(nl: &'a mut Netlist) -> Self {
        Builder { nl, zero: None, one: None }
    }

    // ---------------- primitive-ish helpers ----------------

    /// The constant-0 net (deduplicated).
    pub fn zero(&mut self) -> NetId {
        if let Some(z) = self.zero {
            return z;
        }
        let n = self.nl.net();
        self.nl.add_cell(CellKind::Const { value: false }, vec![], vec![n]);
        self.zero = Some(n);
        n
    }

    /// The constant-1 net (deduplicated).
    pub fn one(&mut self) -> NetId {
        if let Some(o) = self.one {
            return o;
        }
        let n = self.nl.net();
        self.nl.add_cell(CellKind::Const { value: true }, vec![], vec![n]);
        self.one = Some(n);
        n
    }

    /// A constant bus of `width` bits holding `value` (two's complement).
    pub fn const_bus(&mut self, value: i64, width: usize) -> Bus {
        let (z, o) = (self.zero(), self.one());
        Bus((0..width).map(|i| if (value >> i) & 1 == 1 { o } else { z }).collect())
    }

    /// Declare a primary input bus.
    pub fn input(&mut self, name: &str, width: usize) -> Bus {
        let nets: Vec<NetId> = (0..width)
            .map(|_| {
                let n = self.nl.net();
                self.nl.add_cell(CellKind::Input { name: name.to_string() }, vec![], vec![n]);
                n
            })
            .collect();
        self.nl.inputs.push((name.to_string(), nets.clone()));
        Bus(nets)
    }

    /// Declare a top-level output.
    pub fn output(&mut self, name: &str, bus: &Bus) {
        self.nl.outputs.push((name.to_string(), bus.0.clone()));
    }

    /// Single-function LUT cell.
    pub fn lut(&mut self, f: Lut, ins: Vec<NetId>) -> NetId {
        assert_eq!(ins.len(), f.k as usize, "LUT arity");
        let o = self.nl.net();
        self.nl.add_cell(CellKind::Lut { funcs: vec![f] }, ins, vec![o]);
        o
    }

    /// Fractured LUT6_2: two functions of the same ≤5 inputs, one LUT cost.
    pub fn lut_dual(&mut self, f6: Lut, f5: Lut, ins: Vec<NetId>) -> (NetId, NetId) {
        assert!(f6.k as usize == ins.len() && f5.k as usize == ins.len() && ins.len() <= 5);
        let o6 = self.nl.net();
        let o5 = self.nl.net();
        self.nl.add_cell(CellKind::Lut { funcs: vec![f6, f5] }, ins, vec![o6, o5]);
        (o6, o5)
    }

    pub fn not(&mut self, a: NetId) -> NetId {
        self.lut(Lut::not1(), vec![a])
    }

    pub fn and2(&mut self, a: NetId, b: NetId) -> NetId {
        self.lut(Lut::and2(), vec![a, b])
    }

    pub fn xor2(&mut self, a: NetId, b: NetId) -> NetId {
        self.lut(Lut::xor2(), vec![a, b])
    }

    /// Per-bit 2:1 mux: `sel ? b : a`.
    pub fn mux2(&mut self, sel: NetId, a: &Bus, b: &Bus) -> Bus {
        assert_eq!(a.width(), b.width(), "mux2 width");
        Bus((0..a.width())
            .map(|i| self.lut(Lut::mux2(), vec![a.bit(i), b.bit(i), sel]))
            .collect())
    }

    // ---------------- width adaptation ----------------

    /// Sign-extend (free: replicates the MSB net).
    pub fn sext(&mut self, a: &Bus, width: usize) -> Bus {
        assert!(width >= a.width());
        let mut nets = a.0.clone();
        let msb = a.msb();
        nets.resize(width, msb);
        Bus(nets)
    }

    /// Zero-extend.
    pub fn zext(&mut self, a: &Bus, width: usize) -> Bus {
        assert!(width >= a.width());
        let z = self.zero();
        let mut nets = a.0.clone();
        nets.resize(width, z);
        Bus(nets)
    }

    /// Truncate to the low `width` bits.
    pub fn trunc(&self, a: &Bus, width: usize) -> Bus {
        assert!(width <= a.width());
        Bus(a.0[..width].to_vec())
    }

    /// Concatenate (lo first).
    pub fn concat(&self, lo: &Bus, hi: &Bus) -> Bus {
        let mut nets = lo.0.clone();
        nets.extend(&hi.0);
        Bus(nets)
    }

    // ---------------- registers ----------------

    /// Register a bus through FDREs. `ce`/`r` apply to every bit.
    pub fn register(&mut self, d: &Bus, ce: NetId, r: NetId) -> Bus {
        Bus(d
            .0
            .iter()
            .map(|&bit| {
                let q = self.nl.net();
                self.nl.add_cell(CellKind::Fdre, vec![bit, ce, r], vec![q]);
                q
            })
            .collect())
    }

    /// `stages`-deep register delay line.
    pub fn delay(&mut self, d: &Bus, stages: usize, ce: NetId, r: NetId) -> Bus {
        let mut cur = d.clone();
        for _ in 0..stages {
            cur = self.register(&cur, ce, r);
        }
        cur
    }

    // ---------------- carry-chain arithmetic ----------------

    /// Internal: build a carry chain over per-bit (S, DI) nets with the
    /// given carry-in. Returns sum bits (one per stage).
    fn carry_chain(&mut self, s: &[NetId], di: &[NetId], ci: NetId) -> Vec<NetId> {
        assert_eq!(s.len(), di.len());
        let z = self.zero();
        let mut sums = Vec::with_capacity(s.len());
        let mut carry_in = ci;
        for chunk in 0..s.len().div_ceil(CARRY8_WIDTH) {
            let lo = chunk * CARRY8_WIDTH;
            let hi = (lo + CARRY8_WIDTH).min(s.len());
            let used = hi - lo;
            let mut ins = Vec::with_capacity(17);
            for i in 0..CARRY8_WIDTH {
                ins.push(if lo + i < hi { s[lo + i] } else { z });
            }
            for i in 0..CARRY8_WIDTH {
                ins.push(if lo + i < hi { di[lo + i] } else { z });
            }
            ins.push(carry_in);
            let outs: Vec<NetId> = (0..16).map(|_| self.nl.net()).collect();
            self.nl.add_cell(CellKind::Carry8, ins, outs.clone());
            sums.extend(&outs[..used]);
            carry_in = outs[8 + CARRY8_WIDTH - 1]; // CO7 cascades
        }
        sums
    }

    /// Signed add: result width = max(wa, wb) + 1 (never overflows).
    pub fn add(&mut self, a: &Bus, b: &Bus) -> Bus {
        let w = a.width().max(b.width()) + 1;
        self.addsub_w(a, b, w, false)
    }

    /// Signed subtract `a - b`: result width = max + 1.
    pub fn sub(&mut self, a: &Bus, b: &Bus) -> Bus {
        let w = a.width().max(b.width()) + 1;
        self.addsub_w(a, b, w, true)
    }

    /// Add/sub with explicit (wrapping) result width. One LUT per bit:
    /// S = a ^ b (or xnor for sub), DI via the O5 function.
    pub fn addsub_w(&mut self, a: &Bus, b: &Bus, width: usize, sub: bool) -> Bus {
        let ax = self.sext(a, width);
        let bx = self.sext(b, width);
        let mut s_nets = Vec::with_capacity(width);
        let mut di_nets = Vec::with_capacity(width);
        for i in 0..width {
            // O6 = a ^ b (^1 for sub); O5 = b (^1 for sub) — equals the
            // generate when propagate is 0 (see carry.rs docs).
            let f6 = if sub { Lut::from_fn(2, |x| ((x & 1) ^ ((x >> 1) & 1) ^ 1) == 1) } else { Lut::xor2() };
            let f5 = if sub {
                Lut::from_fn(2, |x| ((x >> 1) & 1) == 0)
            } else {
                Lut::from_fn(2, |x| ((x >> 1) & 1) == 1)
            };
            let (s, di) = self.lut_dual(f6, f5, vec![ax.bit(i), bx.bit(i)]);
            s_nets.push(s);
            di_nets.push(di);
        }
        let ci = if sub { self.one() } else { self.zero() };
        Bus(self.carry_chain(&s_nets, &di_nets, ci))
    }

    /// `a + carry_in` at the same width (wrapping): 1 LUT/bit. This is the
    /// lane-split correction primitive for `Conv_3` (and the incrementer).
    pub fn add_carry_in(&mut self, a: &Bus, ci: NetId) -> Bus {
        let w = a.width();
        let s: Vec<NetId> = (0..w).map(|i| self.lut(Lut::buf1(), vec![a.bit(i)])).collect();
        let z = self.zero();
        let di = vec![z; w];
        Bus(self.carry_chain(&s, &di, ci))
    }

    /// Incrementer `a + 1` at the same width (wrapping): 1 LUT/bit.
    pub fn increment(&mut self, a: &Bus) -> Bus {
        let one = self.one();
        self.add_carry_in(a, one)
    }

    /// Gated add/sub used by the array multiplier:
    /// `acc ± (bbit ? a : 0)`, result width = max(w)+1, fused dual-output
    /// LUT3 per bit (S and DI from one LUT).
    pub fn addsub_gated(&mut self, acc: &Bus, a: &Bus, bbit: NetId, sub: bool) -> Bus {
        let w = acc.width().max(a.width()) + 1;
        let accx = self.sext(acc, w);
        let ax = self.sext(a, w);
        let mut s_nets = Vec::with_capacity(w);
        let mut di_nets = Vec::with_capacity(w);
        for i in 0..w {
            // inputs: {acc_i, a_i, bbit}; g = a_i & bbit
            let f_s = if sub {
                // S = acc ^ ~g
                Lut::from_fn(3, |x| {
                    let (acc_b, a_b, b_b) = (x & 1, (x >> 1) & 1, (x >> 2) & 1);
                    (acc_b ^ ((a_b & b_b) ^ 1)) == 1
                })
            } else {
                Lut::from_fn(3, |x| {
                    let (acc_b, a_b, b_b) = (x & 1, (x >> 1) & 1, (x >> 2) & 1);
                    (acc_b ^ (a_b & b_b)) == 1
                })
            };
            let f_di = if sub {
                // DI = ~g (equals generate when S=0)
                Lut::from_fn(3, |x| ((((x >> 1) & 1) & ((x >> 2) & 1)) ^ 1) == 1)
            } else {
                // DI = g
                Lut::from_fn(3, |x| (((x >> 1) & 1) & ((x >> 2) & 1)) == 1)
            };
            let (s, di) = self.lut_dual(f_s, f_di, vec![accx.bit(i), ax.bit(i), bbit]);
            s_nets.push(s);
            di_nets.push(di);
        }
        let ci = if sub { self.one() } else { self.zero() };
        Bus(self.carry_chain(&s_nets, &di_nets, ci))
    }

    /// Signed array multiplier `a * b` → width `wa + wb`, built from
    /// gated-add rows (last row subtracts — b's MSB has negative weight).
    /// Pipeline registers are inserted before each row listed in `cuts`
    /// (used by `Conv_1` to meet 200 MHz). Returns (product, stages).
    pub fn mul_signed(
        &mut self,
        a: &Bus,
        b: &Bus,
        cuts: &[usize],
        ce: NetId,
        rst: NetId,
    ) -> (Bus, usize) {
        let (wa, wb) = (a.width(), b.width());
        assert!(wa >= 2 && wb >= 2, "mul_signed needs >=2-bit operands");
        // Row 0: acc = a & b0, packed two AND-pairs per fractured LUT.
        let b0 = b.bit(0);
        let mut row0 = Vec::with_capacity(wa);
        let mut j = 0;
        while j + 1 < wa {
            let f_hi = Lut::from_fn(3, |x| (((x >> 1) & 1) & ((x >> 2) & 1)) == 1); // a_{j+1} & b0
            let f_lo = Lut::from_fn(3, |x| ((x & 1) & ((x >> 2) & 1)) == 1); // a_j & b0
            let (hi, lo) = self.lut_dual(f_hi, f_lo, vec![a.bit(j), a.bit(j + 1), b0]);
            row0.push(lo);
            row0.push(hi);
            j += 2;
        }
        if j < wa {
            row0.push(self.and2(a.bit(j), b0));
        }
        let mut acc = Bus(row0); // width wa; value = a * b0 (b0 ∈ {0,1} ⇒ fits)
        let mut delayed_b = b.clone();
        let mut b_offset = 0usize; // bits below b_offset already consumed
        let mut delayed_a = a.clone();
        let mut stages = 0usize;
        let mut low_bits: Vec<NetId> = Vec::new(); // finalized product LSBs
        for i in 1..wb {
            if cuts.contains(&i) {
                // Pipeline cut: register acc, the *remaining* operand
                // bits, and already-finalized low bits.
                acc = self.register(&acc, ce, rst);
                delayed_a = self.register(&delayed_a, ce, rst);
                let tail = delayed_b.slice(i - b_offset, delayed_b.width());
                delayed_b = self.register(&tail, ce, rst);
                b_offset = i;
                let lb = Bus(low_bits.clone());
                low_bits = self.register(&lb, ce, rst).0;
                stages += 1;
            }
            // Finalize product bit (i-1) = acc LSB, then add the next row
            // against the remaining high part.
            low_bits.push(acc.bit(0));
            let hi = acc.slice(1, acc.width());
            acc = self.addsub_gated(&hi, &delayed_a, delayed_b.bit(i - b_offset), i == wb - 1);
        }
        let mut nets = low_bits;
        nets.extend(&acc.0);
        let full = Bus(nets);
        let w = wa + wb;
        let prod = if full.width() >= w {
            self.trunc(&full, w)
        } else {
            self.sext(&full, w)
        };
        (prod, stages)
    }

    // ---------------- comparison / control ----------------

    /// `bus == k` via a LUT tree.
    pub fn eq_const(&mut self, a: &Bus, k: u64) -> NetId {
        // Level 1: up to 6 bits per LUT comparing against the constant.
        let mut terms: Vec<NetId> = Vec::new();
        for chunk in a.0.chunks(6) {
            let want: u64 = {
                let base = terms.len() * 6;
                let mut w = 0u64;
                for (i, _) in chunk.iter().enumerate() {
                    if (k >> (base + i)) & 1 == 1 {
                        w |= 1 << i;
                    }
                }
                w
            };
            let kk = chunk.len() as u8;
            let f = Lut::from_fn(kk, move |x| x == want);
            terms.push(self.lut(f, chunk.to_vec()));
        }
        // AND-reduce.
        while terms.len() > 1 {
            let mut next = Vec::new();
            for pair in terms.chunks(2) {
                next.push(if pair.len() == 2 { self.and2(pair[0], pair[1]) } else { pair[0] });
            }
            terms = next;
        }
        terms[0]
    }

    /// Modulo-`n` counter: register + incrementer + wrap mux. Returns
    /// (count_bus, wrap_pulse) — wrap_pulse is high on the last count.
    pub fn counter_mod(&mut self, n: u64, ce: NetId, rst: NetId) -> (Bus, NetId) {
        assert!(n >= 2);
        let width = (64 - (n - 1).leading_zeros()) as usize;
        // Feedback: q -> inc -> mux(wrap ? 0 : inc) -> reg -> q.
        // Build with a placeholder: allocate q nets first via FDRE cells
        // whose D we wire after constructing the logic.
        // Simpler: construct incrementally using a register we close the
        // loop on manually.
        let q_nets: Vec<NetId> = (0..width).map(|_| self.nl.net()).collect();
        let q = Bus(q_nets.clone());
        let inc = self.increment(&q);
        let wrap = self.eq_const(&q, n - 1);
        let zero_bus = self.const_bus(0, width);
        let d = self.mux2(wrap, &inc, &zero_bus);
        for i in 0..width {
            self.nl.add_cell(CellKind::Fdre, vec![d.bit(i), ce, rst], vec![q_nets[i]]);
        }
        (q, wrap)
    }

    /// N:1 mux tree, 4 items per LUT6 level (the mapping Vivado emits for
    /// wide muxes without F7/F8 muxes). `sel` is consumed 2 bits per level.
    pub fn mux_tree(&mut self, items: &[NetId], sel: &[NetId]) -> NetId {
        assert!(!items.is_empty());
        if items.len() == 1 {
            return items[0];
        }
        assert!(!sel.is_empty(), "mux_tree ran out of select bits");
        let mut next = Vec::new();
        for chunk in items.chunks(4) {
            if chunk.len() == 1 {
                next.push(chunk[0]);
                continue;
            }
            let n = chunk.len();
            let selbits = if n == 2 { 1 } else { 2 };
            let mut ins = chunk.to_vec();
            ins.extend(&sel[..selbits.min(sel.len())]);
            let used_sel = ins.len() - n;
            let f = Lut::from_fn((n + used_sel) as u8, move |x| {
                let s = ((x >> n) as usize) & ((1 << used_sel) - 1);
                let s = s.min(n - 1);
                (x >> s) & 1 == 1
            });
            next.push(self.lut(f, ins));
        }
        let drop = 2.min(sel.len());
        self.mux_tree(&next, &sel[drop..])
    }

    /// Bus-wide N:1 mux tree. All item buses must share a width.
    pub fn mux_bus_tree(&mut self, items: &[Bus], sel: &Bus) -> Bus {
        let w = items[0].width();
        assert!(items.iter().all(|b| b.width() == w), "mux item widths differ");
        Bus((0..w)
            .map(|bit| {
                let slice: Vec<NetId> = items.iter().map(|b| b.bit(bit)).collect();
                self.mux_tree(&slice, &sel.0)
            })
            .collect())
    }

    /// Requantize: arithmetic-shift-right by the constant `shift`, then
    /// saturate into `out_bits`. (Rounding is handled upstream by
    /// injecting a +half constant into the accumulator.) Overflow is
    /// detected by checking that all accumulator bits above the selected
    /// field agree with the field's sign bit.
    pub fn requant(&mut self, acc: &Bus, shift: u32, out_bits: u32) -> Bus {
        let need = shift as usize + out_bits as usize;
        let accx = if acc.width() < need + 1 { self.sext(acc, need + 1) } else { acc.clone() };
        let field = accx.slice(shift as usize, shift as usize + out_bits as usize);
        let field_sign = field.msb();
        // Bits that must all equal field_sign for the value to fit.
        let high: Vec<NetId> =
            (shift as usize + out_bits as usize..accx.width()).map(|i| accx.bit(i)).collect();
        let mut diffs: Vec<NetId> =
            high.iter().map(|&h| self.xor2(h, field_sign)).collect();
        // OR-reduce the diffs (6 per LUT).
        while diffs.len() > 1 {
            let mut next = Vec::new();
            for chunk in diffs.chunks(6) {
                if chunk.len() == 1 {
                    next.push(chunk[0]);
                } else {
                    let f = Lut::from_fn(chunk.len() as u8, |x| x != 0);
                    next.push(self.lut(f, chunk.to_vec()));
                }
            }
            diffs = next;
        }
        let ovf = diffs.pop().unwrap_or_else(|| self.zero());
        let acc_sign = accx.msb();
        // out bit i = ovf ? (i == msb ? acc_sign : !acc_sign) : field_i
        Bus((0..out_bits as usize)
            .map(|i| {
                let is_msb = i == out_bits as usize - 1;
                let f = Lut::from_fn(3, move |x| {
                    let (fld, ov, sg) = (x & 1, (x >> 1) & 1, (x >> 2) & 1);
                    if ov == 1 {
                        if is_msb {
                            sg == 1
                        } else {
                            sg == 0
                        }
                    } else {
                        fld == 1
                    }
                });
                self.lut(f, vec![field.bit(i), ovf, acc_sign])
            })
            .collect())
    }

    // ---------------- DSP instantiation ----------------

    /// Instantiate a DSP48E2. Buses narrower than the ports are
    /// sign-extended; `zmux` is a 2-bit bus (00=Zero 01=P 10=C).
    pub fn dsp(
        &mut self,
        cfg: dsp48::Config,
        a: &Bus,
        b: &Bus,
        c: &Bus,
        d: &Bus,
        zmux: &Bus,
        ce: NetId,
    ) -> Bus {
        let ax = self.sext(a, 27);
        let bx = self.sext(b, 18);
        let cx = self.sext(c, 48);
        let dx = self.sext(d, 27);
        assert_eq!(zmux.width(), 2);
        let mut ins = ax.0;
        ins.extend(&bx.0);
        ins.extend(&cx.0);
        ins.extend(&dx.0);
        ins.extend(&zmux.0);
        ins.push(ce);
        let p: Vec<NetId> = (0..48).map(|_| self.nl.net()).collect();
        self.nl.add_cell(CellKind::Dsp48e2 { cfg }, ins, p.clone());
        Bus(p)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netlist::sim::Sim;
    use crate::util::prop::forall;

    /// Helper: build a 2-input arithmetic testbench and return closure-ish
    /// evaluation via fresh sims.
    fn eval2(build: impl Fn(&mut Builder, &Bus, &Bus) -> Bus, wa: usize, wb: usize, a: i64, b: i64) -> i64 {
        let mut nl = Netlist::new();
        let mut bld = Builder::new(&mut nl);
        let ab = bld.input("a", wa);
        let bb = bld.input("b", wb);
        let y = build(&mut bld, &ab, &bb);
        bld.output("y", &y);
        let mut sim = Sim::new(&nl).unwrap();
        sim.set_input("a", (a as u64) & ((1 << wa) - 1));
        sim.set_input("b", (b as u64) & ((1 << wb) - 1));
        sim.settle();
        sim.output_signed("y")
    }

    #[test]
    fn add_sub_basic() {
        assert_eq!(eval2(|b, x, y| b.add(x, y), 8, 8, 100, 27), 127);
        assert_eq!(eval2(|b, x, y| b.add(x, y), 8, 8, -128, -128), -256);
        assert_eq!(eval2(|b, x, y| b.sub(x, y), 8, 8, -128, 127), -255);
        assert_eq!(eval2(|b, x, y| b.sub(x, y), 8, 8, 5, 9), -4);
    }

    #[test]
    fn prop_addsub_matches_integers() {
        forall("builder add/sub == i64", 200, |g| {
            let wa = g.usize_in(2, 12);
            let wb = g.usize_in(2, 12);
            let a = g.signed_bits(wa as u32);
            let b = g.signed_bits(wb as u32);
            let s = eval2(|bl, x, y| bl.add(x, y), wa, wb, a, b);
            let d = eval2(|bl, x, y| bl.sub(x, y), wa, wb, a, b);
            if s == a + b && d == a - b {
                Ok(())
            } else {
                Err(format!("wa={wa} wb={wb} a={a} b={b}: add={s} sub={d}"))
            }
        });
    }

    #[test]
    fn increment_wraps() {
        let mut nl = Netlist::new();
        let mut b = Builder::new(&mut nl);
        let x = b.input("x", 4);
        let y = b.increment(&x);
        b.output("y", &y);
        let mut sim = Sim::new(&nl).unwrap();
        for v in 0..16u64 {
            sim.set_input("x", v);
            sim.settle();
            assert_eq!(sim.output_unsigned("y"), (v + 1) % 16);
        }
    }

    #[test]
    fn mul_signed_exhaustive_4x4() {
        for a in -8i64..8 {
            for b in -8i64..8 {
                let got = eval2(
                    |bl, x, y| {
                        let ce = bl.one();
                        let r = bl.zero();
                        bl.mul_signed(x, y, &[], ce, r).0
                    },
                    4,
                    4,
                    a,
                    b,
                );
                assert_eq!(got, a * b, "a={a} b={b}");
            }
        }
    }

    #[test]
    fn prop_mul_signed_matches() {
        forall("mul_signed == *", 120, |g| {
            let wa = g.usize_in(2, 10);
            let wb = g.usize_in(2, 10);
            let a = g.signed_bits(wa as u32);
            let b = g.signed_bits(wb as u32);
            let got = eval2(
                |bl, x, y| {
                    let ce = bl.one();
                    let r = bl.zero();
                    bl.mul_signed(x, y, &[], ce, r).0
                },
                wa,
                wb,
                a,
                b,
            );
            if got == a * b {
                Ok(())
            } else {
                Err(format!("wa={wa} wb={wb}: {a}*{b} -> {got}"))
            }
        });
    }

    #[test]
    fn mul_pipelined_latency_and_value() {
        // Pipeline after row 4: output lags by 1 cycle but is exact.
        let mut nl = Netlist::new();
        let mut b = Builder::new(&mut nl);
        let x = b.input("x", 8);
        let y = b.input("y", 8);
        let ce = b.one();
        let r = b.zero();
        let (p, stages) = b.mul_signed(&x, &y, &[4], ce, r);
        assert_eq!(stages, 1);
        b.output("p", &p);
        let mut sim = Sim::new(&nl).unwrap();
        sim.set_input("x", (-77i64 as u64) & 0xFF);
        sim.set_input("y", 55);
        sim.settle();
        sim.tick(); // one pipeline stage
        assert_eq!(sim.output_signed("p"), -77 * 55);
    }

    #[test]
    fn eq_const_wide() {
        let mut nl = Netlist::new();
        let mut b = Builder::new(&mut nl);
        let x = b.input("x", 9);
        let hit = b.eq_const(&x, 389);
        b.output("hit", &Bus(vec![hit]));
        let mut sim = Sim::new(&nl).unwrap();
        for v in [0u64, 388, 389, 390, 511] {
            sim.set_input("x", v);
            sim.settle();
            assert_eq!(sim.output_unsigned("hit") == 1, v == 389, "v={v}");
        }
    }

    #[test]
    fn counter_mod_9_sequence() {
        let mut nl = Netlist::new();
        let mut b = Builder::new(&mut nl);
        let ce = b.one();
        let r = b.zero();
        let (q, wrap) = b.counter_mod(9, ce, r);
        b.output("q", &q);
        b.output("wrap", &Bus(vec![wrap]));
        let mut sim = Sim::new(&nl).unwrap();
        let mut seen = Vec::new();
        for _ in 0..20 {
            seen.push(sim.output_unsigned("q"));
            sim.tick();
        }
        assert_eq!(&seen[..10], &[0, 1, 2, 3, 4, 5, 6, 7, 8, 0]);
        assert_eq!(seen[9..18], seen[0..9]);
    }

    #[test]
    fn mux2_and_extensions() {
        let mut nl = Netlist::new();
        let mut b = Builder::new(&mut nl);
        let x = b.input("x", 4);
        let sx = b.sext(&x, 8);
        let zx = b.zext(&x, 8);
        let sel = b.input("sel", 1);
        let y = b.mux2(sel.bit(0), &sx, &zx);
        b.output("y", &y);
        let mut sim = Sim::new(&nl).unwrap();
        sim.set_input("x", 0b1010); // -6 signed, 10 unsigned
        sim.set_input("sel", 0);
        sim.settle();
        assert_eq!(sim.output_signed("y"), -6);
        sim.set_input("sel", 1);
        sim.settle();
        assert_eq!(sim.output_signed("y"), 10);
    }

    #[test]
    fn dsp_builder_macc() {
        use crate::fabric::dsp48::Config;
        let mut nl = Netlist::new();
        let mut b = Builder::new(&mut nl);
        let a = b.input("a", 8);
        let bb = b.input("b", 8);
        let zm = b.input("zm", 2);
        let c = b.const_bus(0, 48);
        let d = b.const_bus(0, 27);
        let ce = b.one();
        let p = b.dsp(Config::full_macc(false), &a, &bb, &c, &d, &zm, ce);
        b.output("p", &p);
        let mut sim = Sim::new(&nl).unwrap();
        let seq = [(3i64, 4i64, 0u64), (-5, 6, 1), (0, 0, 1), (0, 0, 1), (0, 0, 1)];
        for (av, bv, zmv) in seq {
            sim.set_input("a", (av as u64) & 0xFF);
            sim.set_input("b", (bv as u64) & 0xFF);
            sim.set_input("zm", zmv);
            sim.settle();
            sim.tick();
        }
        assert_eq!(sim.output_signed("p"), 3 * 4 - 5 * 6);
    }

    #[test]
    fn mux_tree_9to1() {
        let mut nl = Netlist::new();
        let mut b = Builder::new(&mut nl);
        let items: Vec<Bus> = (0..9).map(|i| b.input(&format!("i{i}"), 8)).collect();
        let sel = b.input("sel", 4);
        let y = b.mux_bus_tree(&items, &sel);
        b.output("y", &y);
        let luts = nl.census()[&crate::fabric::Prim::Lut];
        assert!(luts <= 4 * 8, "9:1x8 mux too costly: {luts} LUTs");
        let mut sim = Sim::new(&nl).unwrap();
        for (i, v) in [(0u64, 11u64), (3, 44), (4, 55), (7, 88), (8, 99)] {
            for j in 0..9 {
                sim.set_input(&format!("i{j}"), j * 11 + 11);
            }
            sim.set_input("sel", i);
            sim.settle();
            assert_eq!(sim.output_unsigned("y"), v, "sel={i}");
        }
    }

    #[test]
    fn requant_saturates_and_shifts() {
        let mut nl = Netlist::new();
        let mut b = Builder::new(&mut nl);
        let acc = b.input("acc", 20);
        let y = b.requant(&acc, 4, 8);
        b.output("y", &y);
        let mut sim = Sim::new(&nl).unwrap();
        for (acc_v, want) in [
            (160i64, 10i64),
            (-160, -10),
            (127 << 4, 127),
            (128 << 4, 127),      // just over -> saturate
            (-(128 << 4), -128),  // exactly min
            (-(129 << 4), -128),  // under -> saturate
            (100_000, 127),
            (-100_000, -128),
            (15, 0),
            (-1, -1), // floor(-1/16) = -1
        ] {
            sim.set_input("acc", (acc_v as u64) & ((1 << 20) - 1));
            sim.settle();
            assert_eq!(sim.output_signed("y"), want, "acc={acc_v}");
        }
    }

    #[test]
    fn prop_requant_matches_fixed() {
        use crate::fixed::{requantize, Round};
        forall("netlist requant == fixed::requantize", 150, |g| {
            let shift = g.usize_in(0, 8) as u32;
            let aw = g.usize_in((shift as usize + 9).max(10), 24);
            let acc_v = g.signed_bits(aw as u32);
            let mut nl = Netlist::new();
            let mut b = Builder::new(&mut nl);
            let acc = b.input("acc", aw);
            let y = b.requant(&acc, shift, 8);
            b.output("y", &y);
            let mut sim = Sim::new(&nl).unwrap();
            sim.set_input("acc", (acc_v as u64) & ((1u64 << aw) - 1));
            sim.settle();
            let got = sim.output_signed("y");
            let want = requantize(acc_v, shift, Round::Truncate, 8);
            if got == want {
                Ok(())
            } else {
                Err(format!("aw={aw} shift={shift} acc={acc_v}: got {got} want {want}"))
            }
        });
    }

    #[test]
    fn census_costs_are_sane() {
        // 8x8 multiplier should cost on the order of 70 LUTs — the basis
        // of Conv_1's Table II footprint.
        let mut nl = Netlist::new();
        let mut b = Builder::new(&mut nl);
        let x = b.input("x", 8);
        let y = b.input("y", 8);
        let ce = b.one();
        let r = b.zero();
        let (p, _) = b.mul_signed(&x, &y, &[], ce, r);
        b.output("p", &p);
        let census = nl.census();
        let luts = census[&crate::fabric::Prim::Lut];
        assert!(
            (55..=95).contains(&luts),
            "8x8 logic multiplier LUT count out of expected envelope: {luts}"
        );
    }
}
