//! Regeneration of every table in the paper's evaluation, plus the
//! supporting sweeps (DESIGN.md experiment index).
//!
//! * [`table1`] — Characteristics of the developed convolution IPs.
//! * [`table2`] — Resource utilization (LUT/Reg/CLB/DSP/WNS/Power) on the
//!   ZCU104 at 200 MHz, 8-bit, 3×3 — measured through our synthesis, STA
//!   and power flows, with the paper's published numbers alongside.
//! * [`table3`] — Comparison of optimization techniques, with the
//!   qualitative ratings *derived* from quantitative policy sweeps rather
//!   than asserted.
//! * [`opt_table`] — the netlist optimization pass pipeline's per-engine
//!   report: pre/post primitive counts and per-pass work at O2
//!   (`acf tables --table opt`).
//! * [`sweep_adaptation`] — throughput vs device across policies (Sweep-A).
//! * [`sweep_precision`] — operand-width sweep per IP (Sweep-B).
//! * [`plan_table`] — the unified engine-plan report: one row per planned
//!   engine (conv, FC, max-pool, fused ReLU) with instances, work,
//!   cycles, and resources.
//! * [`fleet_table`] / [`serve_table`] / [`serve_group_table`] /
//!   [`rebalance_table`] — the serving tier's modeled-fleet and
//!   measured-fleet reports (`acf serve`), broken out per device group
//!   for heterogeneous fleets, plus the dynamic-rebalance timeline.
//! * [`tenant_table`] — the multi-tenant serving report: one row per
//!   tenant with its model, quota, admission fate (accepted/shed %),
//!   and latency quantiles against its SLO (`acf serve --models`).
//! * [`scenario_table`] / [`scenario_tenant_table`] /
//!   [`fault_timeline_table`] — the deterministic scenario harness's
//!   verdict: per-phase SLO checks, the per-tenant phase breakdown, and
//!   the fault injection timeline with recovery times
//!   (`acf serve --scenario`).

use crate::cnn::model::{Layer, Model};
use crate::fabric::device::{by_name, catalog, Device};
use crate::ips::{self, ConvKind, ConvParams};
use crate::planner::{baselines, plan, Plan, Policy};
use crate::power;
use crate::serve::{FleetPlan, FleetSnapshot};
use crate::sta;
use crate::synth::synthesize;
use crate::util::table::{fnum, Table};

/// Paper Table II reference rows: (LUTs, Regs, CLBs, DSPs, WNS, Power).
pub const PAPER_TABLE2: [(u64, u64, u64, u64, f64, f64); 4] = [
    (105, 54, 15, 0, 2.596, 0.593), // Conv_1
    (30, 22, 5, 1, 2.276, 0.594),   // Conv_2
    (45, 32, 10, 1, 2.086, 0.594),  // Conv_3
    (42, 23, 8, 2, 2.870, 0.596),   // Conv_4
];

/// Table I — characteristics (regenerated from library metadata).
pub fn table1() -> Table {
    let mut t = Table::new(vec!["IP", "DSP Usage", "Logic Usage", "Key Features"]);
    for kind in ConvKind::ALL {
        let c = ips::characteristics(kind);
        t.row(vec![kind.name(), c.dsp_usage, c.logic_usage, c.key_features]);
    }
    t
}

/// One measured Table II row.
#[derive(Debug, Clone)]
pub struct Table2Row {
    pub kind: ConvKind,
    pub luts: u64,
    pub regs: u64,
    pub clbs: u64,
    pub dsps: u64,
    pub wns_ns: f64,
    pub power_w: f64,
}

/// Measure the Table II rows on `dev` at `clock_mhz`.
pub fn table2_rows(dev: &Device, clock_mhz: f64) -> Vec<Table2Row> {
    let params = ConvParams::paper_8bit();
    ConvKind::ALL
        .iter()
        .map(|&kind| {
            let ip = ips::generate(kind, &params).expect("paper config always feasible");
            let u = synthesize(&ip.netlist);
            let t = sta::analyze(&ip.netlist, clock_mhz, dev.speed_derate).expect("valid netlist");
            let p = power::estimate(&u, dev, clock_mhz, None);
            Table2Row {
                kind,
                luts: u.luts,
                regs: u.regs,
                clbs: u.clbs,
                dsps: u.dsps,
                wns_ns: t.wns_ns,
                power_w: p.total_w(),
            }
        })
        .collect()
}

/// Table II — measured vs paper.
pub fn table2(dev: &Device, clock_mhz: f64) -> Table {
    let rows = table2_rows(dev, clock_mhz);
    let mut t = Table::new(vec![
        "IP", "LUTs", "Regs", "CLBs", "DSPs", "WNS (ns)", "Power (W)", "paper LUTs", "paper Regs",
        "paper CLBs", "paper DSPs", "paper WNS", "paper Power",
    ])
    .numeric();
    for (i, r) in rows.iter().enumerate() {
        let p = PAPER_TABLE2[i];
        t.row(vec![
            r.kind.name().to_string(),
            r.luts.to_string(),
            r.regs.to_string(),
            r.clbs.to_string(),
            r.dsps.to_string(),
            fnum(r.wns_ns, 3),
            fnum(r.power_w, 3),
            p.0.to_string(),
            p.1.to_string(),
            p.2.to_string(),
            p.3.to_string(),
            fnum(p.4, 3),
            fnum(p.5, 3),
        ]);
    }
    t
}

/// The netlist optimization pass pipeline's report: every shipped engine
/// generated *raw*, then optimized at O2, with pre → post primitive
/// counts and the per-pass removal breakdown. This is the pre/post face
/// of the `netlist::opt` pipeline — `table2` always reports the
/// *optimized* numbers, this table shows what the passes earned.
pub fn opt_table() -> Table {
    use crate::fabric::Prim;
    use crate::netlist::opt::{optimize_at, OptLevel};
    let p = ConvParams::paper_8bit();
    let mut engines: Vec<(&'static str, crate::netlist::Netlist)> = Vec::new();
    for kind in ConvKind::ALL {
        let ip = match kind {
            ConvKind::Conv1 => ips::conv1::generate(&p),
            ConvKind::Conv2 => ips::conv2::generate(&p),
            ConvKind::Conv3 => ips::conv3::generate(&p),
            ConvKind::Conv4 => ips::conv4::generate(&p),
        }
        .expect("paper config always feasible");
        engines.push((kind.name(), ip.netlist));
    }
    engines.push(("FC", ips::fc::generate(&p, 32).expect("fc fan-in 32 feasible").netlist));
    engines.push(("MaxPool", ips::pool::generate(8, 4).netlist));
    engines.push(("ReLU", ips::relu::generate(8).netlist));
    let mut t = Table::new(vec![
        "engine", "LUTs", "FFs", "CARRY8", "cells-", "nets-", "retabled", "rounds", "per-pass cells-",
    ])
    .numeric();
    for (name, mut nl) in engines {
        let rep = optimize_at(&mut nl, OptLevel::O2);
        let arrow = |p: Prim| format!("{} -> {}", rep.pre_count(p), rep.post_count(p));
        let per_pass = rep
            .passes
            .iter()
            .filter(|s| s.cells_removed > 0)
            .map(|s| format!("{} {}", s.pass, s.cells_removed))
            .collect::<Vec<_>>()
            .join(", ");
        t.row(vec![
            name.to_string(),
            arrow(Prim::Lut),
            arrow(Prim::Ff),
            arrow(Prim::Carry8),
            rep.cells_removed().to_string(),
            rep.nets_removed().to_string(),
            rep.passes.iter().map(|s| s.luts_retabled).sum::<usize>().to_string(),
            rep.iterations.to_string(),
            if per_pass.is_empty() { "none".into() } else { per_pass },
        ]);
    }
    t
}

/// The unified engine-plan report: every planned engine — convolution,
/// FC, max-pool, and fused ReLU alike — as one row, plus a totals row.
/// This is the user-facing face of the engine registry: the formerly
/// "free" pool/activation layers show their instances and resources here.
pub fn plan_table(plan: &Plan) -> Table {
    let mut t = Table::new(vec![
        "layer", "engine", "inst", "work/img", "cyc/img", "LUTs", "Regs", "DSPs", "BRAM18",
    ])
    .numeric();
    for ep in &plan.engines {
        t.row(vec![
            ep.layer.to_string(),
            ep.kind.name().to_string(),
            ep.instances.to_string(),
            ep.work.to_string(),
            format!("{:.0}", ep.cycles_per_image),
            ep.util.luts.to_string(),
            ep.util.regs.to_string(),
            ep.util.dsps.to_string(),
            ep.util.bram18.to_string(),
        ]);
    }
    t.row(vec![
        "".into(),
        "total".into(),
        plan.engines.iter().map(|e| e.instances).sum::<u64>().to_string(),
        "".into(),
        "".into(),
        plan.total.luts.to_string(),
        plan.total.regs.to_string(),
        plan.total.dsps.to_string(),
        plan.total.bram18.to_string(),
    ]);
    t
}

/// The fleet-plan report: one row per device group (how each part was
/// split into replicas, its modeled throughput, its pressure against the
/// *undivided* part, and its coefficient-inclusive BRAM bill), plus a
/// fleet totals row carrying the replica sum, the modeled static power of
/// the mix, and the SLO verdict. Multi-model (zoo) plans tag each group's
/// device with the model it carries, e.g. `zcu104 [lenet-wide-2x]`.
pub fn fleet_table(fp: &FleetPlan) -> Table {
    let mut t = Table::new(vec![
        "device",
        "replicas",
        "img/s per replica",
        "img/s group (modeled)",
        "LUT %",
        "DSP %",
        "BRAM18 (incl. coef)",
        "static W",
        "meets SLO",
    ])
    .numeric();
    let zoo = fp.models.len() > 1;
    for g in &fp.groups {
        let (dsp, lut) = g.pressure();
        let device = if zoo {
            let model =
                fp.models.get(g.model_id).map(|m| m.name.as_str()).unwrap_or("?");
            format!("{} [{}]", g.device.name, model)
        } else {
            g.device.name.clone()
        };
        t.row(vec![
            device,
            g.replicas.to_string(),
            format!("{:.0}", g.per_replica.images_per_sec),
            format!("{:.0}", g.group_img_s),
            format!("{:.1}", lut * 100.0),
            format!("{:.1}", dsp * 100.0),
            format!("{}/{}", g.total.bram18, g.device.bram18),
            format!("{:.3}", g.device.static_w),
            "".into(),
        ]);
    }
    t.row(vec![
        "fleet".into(),
        fp.replicas().to_string(),
        "".into(),
        format!("{:.0}", fp.fleet_img_s),
        "".into(),
        "".into(),
        "".into(),
        format!("{:.3}", fp.static_w),
        match fp.target_img_s {
            Some(tgt) => format!("{} (target {tgt:.0})", if fp.meets_target { "yes" } else { "NO" }),
            None => "n/a".into(),
        },
    ]);
    t
}

/// The measured serving report: one row per replica (dispatch balance and
/// utilization, tagged with its device group). Fleet-level latency and
/// throughput live on [`FleetSnapshot`] itself; `acf serve` prints them
/// under this table.
pub fn serve_table(snap: &FleetSnapshot) -> Table {
    let mut t = Table::new(vec![
        "replica", "device", "images", "batches", "img/batch", "busy s", "util %",
    ])
    .numeric();
    for (ri, r) in snap.replicas.iter().enumerate() {
        let label = snap.groups.get(r.group).map(|g| g.label.as_str()).unwrap_or("?");
        t.row(vec![
            ri.to_string(),
            label.to_string(),
            r.images.to_string(),
            r.batches.to_string(),
            if r.batches > 0 { format!("{:.1}", r.images as f64 / r.batches as f64) } else { "-".into() },
            format!("{:.3}", r.busy_secs),
            format!("{:.1}", r.utilization * 100.0),
        ]);
    }
    t
}

/// The per-device-group serving report: measured latency quantiles,
/// utilization, queue pressure, and the drain summary broken out per
/// physical part — the view that shows which silicon is falling behind
/// in a heterogeneous fleet, and whether every retired replica actually
/// finished its in-flight work ("drains" counts clean drains vs drain-
/// deadline misses; a miss also shows how many images were left behind).
pub fn serve_group_table(snap: &FleetSnapshot) -> Table {
    let mut t = Table::new(vec![
        "device",
        "replicas",
        "images",
        "util %",
        "p50 ms",
        "p95 ms",
        "p99 ms",
        "in-flight peak",
        "drains ok/late",
    ])
    .numeric();
    for g in &snap.groups {
        let drains = if g.drain_failed > 0 {
            format!("{}/{} ({} img stuck)", g.drained, g.drain_failed, g.drain_leftover_images)
        } else {
            format!("{}/0", g.drained)
        };
        t.row(vec![
            g.label.clone(),
            g.replicas.to_string(),
            g.images.to_string(),
            format!("{:.1}", g.utilization * 100.0),
            fnum(g.p50_ms, 2),
            fnum(g.p95_ms, 2),
            fnum(g.p99_ms, 2),
            g.in_flight_peak.to_string(),
            drains,
        ]);
    }
    t
}

/// The per-tenant serving report: one row per configured tenant — the
/// model it routes to, its admission quota, how admission treated it
/// (accepted / shed %), and its measured latency quantiles with the SLO
/// verdict when the tenant declared a p99 bound. Printed by
/// `acf serve --models m1:t1,m2:t2` under the group table; empty rosters
/// (single-tenant serves) render no rows.
pub fn tenant_table(snap: &FleetSnapshot) -> Table {
    let mut t = Table::new(vec![
        "tenant", "model", "quota", "accepted", "shed %", "completed", "p50 ms", "p95 ms",
        "p99 ms", "p99 SLO",
    ])
    .numeric();
    for tn in &snap.tenants {
        let slo = match tn.p99_slo_ms {
            Some(ms) => {
                let ok = tn.completed == 0 || tn.p99_ms <= ms;
                format!("{} ms {}", fnum(ms, 1), if ok { "ok" } else { "MISS" })
            }
            None => "n/a".into(),
        };
        t.row(vec![
            tn.name.clone(),
            tn.model.clone(),
            fnum(tn.quota, 2),
            tn.accepted.to_string(),
            format!("{:.1}", tn.shed_pct),
            tn.completed.to_string(),
            fnum(tn.p50_ms, 2),
            fnum(tn.p95_ms, 2),
            fnum(tn.p99_ms, 2),
            slo,
        ]);
    }
    t
}

/// The rebalance timeline: one row per scale action, in order — when it
/// fired, which device group it resized, how, and the signal that
/// triggered it. Printed by `acf serve --rebalance` after the load run.
pub fn rebalance_table(events: &[crate::serve::RebalanceEvent]) -> Table {
    let mut t =
        Table::new(vec!["t (s)", "device", "action", "replicas", "trigger"]).numeric();
    for e in events {
        t.row(vec![
            fnum(e.at_secs, 2),
            e.label.clone(),
            e.action.to_string(),
            format!("{} -> {}", e.from, e.to),
            e.reason.clone(),
        ]);
    }
    t
}

/// The scenario verdict table: one row per phase — offered load and its
/// fate (accepted / shed / dropped), the phase-window latency
/// quantiles, and each configured assertion as `name actual<=limit`
/// with the failing ones marked. Printed by `acf serve --scenario`.
pub fn scenario_table(report: &crate::serve::ScenarioReport) -> Table {
    let mut t = Table::new(vec![
        "phase", "requests", "accepted", "shed %", "drops", "p50 ms", "p99 ms", "checks",
        "verdict",
    ])
    .numeric();
    for p in &report.phases {
        let checks = if p.checks.is_empty() {
            "none".to_string()
        } else {
            p.checks
                .iter()
                .map(|c| {
                    let mark = if c.passed { "" } else { " FAIL" };
                    format!("{} {}<={}{}", c.name, fnum(c.actual, 1), fnum(c.limit, 1), mark)
                })
                .collect::<Vec<_>>()
                .join("; ")
        };
        t.row(vec![
            p.name.clone(),
            p.requests.to_string(),
            p.accepted.to_string(),
            format!("{:.1}", p.shed_pct),
            p.drops.to_string(),
            fnum(p.p50_ms, 2),
            fnum(p.p99_ms, 2),
            checks,
            if p.passed { "PASS".into() } else { "FAIL".into() },
        ]);
    }
    t
}

/// The per-tenant scenario breakdown: one row per (phase, tenant) — how
/// the phase's offered load split across the roster, who admission shed
/// and how hard, and each tenant's phase-window p99. Printed by
/// `acf serve --scenario` under the verdict table for multi-tenant
/// scenarios; untenanted scenarios produce no rows.
pub fn scenario_tenant_table(report: &crate::serve::ScenarioReport) -> Table {
    let mut t = Table::new(vec![
        "phase", "tenant", "model", "offered", "accepted", "shed %", "completed", "p99 ms",
    ])
    .numeric();
    for p in &report.phases {
        for tn in &p.tenants {
            t.row(vec![
                p.name.clone(),
                tn.name.clone(),
                tn.model.clone(),
                tn.offered.to_string(),
                tn.accepted.to_string(),
                format!("{:.1}", tn.shed_pct),
                tn.completed.to_string(),
                fnum(tn.p99_ms, 2),
            ]);
        }
    }
    t
}

/// The fault injection timeline: one row per injected fault — when it
/// fired, what it did, and how long the fleet took to return under its
/// pre-fault envelope ("never" marks an unrecovered fault). Printed by
/// `acf serve --scenario` under the verdict table.
pub fn fault_timeline_table(faults: &[crate::serve::FaultOutcome]) -> Table {
    let mut t =
        Table::new(vec!["t (s)", "phase", "fault", "group", "detail", "recovery"]).numeric();
    for f in faults {
        let recovery = match f.recovery_ms {
            Some(ms) => format!("{} ms", fnum(ms, 1)),
            None => "never".into(),
        };
        t.row(vec![
            fnum(f.at_ms / 1e3, 3),
            f.phase.to_string(),
            f.kind.clone(),
            f.group.to_string(),
            f.detail.clone(),
            recovery,
        ]);
    }
    t
}

/// The trace critical-path table: one row per request stage in pipeline
/// order (mean and nearest-rank p99 over every request in the trace).
/// Printed by `acf serve --trace` after the load run — the per-stage
/// answer to "where does a request's time go".
pub fn trace_summary(stats: &[crate::trace::StageStat]) -> Table {
    let mut t = Table::new(vec!["stage", "spans", "mean ms", "p99 ms"]).numeric();
    for s in stats {
        t.row(vec![
            s.stage.to_string(),
            s.count.to_string(),
            fnum(s.mean_ms, 3),
            fnum(s.p99_ms, 3),
        ]);
    }
    t
}

/// A 12-bit variant of the tiny model (precision stressor for Table III).
pub fn lenet_tiny_12bit() -> Model {
    let mut m = Model::lenet_tiny();
    m.name = "lenet-tiny-12b".into();
    for layer in &mut m.layers {
        match layer {
            Layer::Conv { params, .. } | Layer::Fc { params, .. } => {
                params.data_bits = 12;
                params.coef_bits = 12;
                params.shift = 11;
            }
            Layer::MaxPool => {}
        }
    }
    m
}

/// Quantitative evidence behind one Table III column for one policy.
#[derive(Debug, Clone)]
pub struct PolicyAssessment {
    pub policy: String,
    /// Devices (of the catalog) where planning FAILED.
    pub failed_devices: usize,
    pub total_devices: usize,
    /// Can it deploy the 12-bit model at all?
    pub multi_precision: bool,
    /// throughput(wide model)/throughput(tiny model) on the ZCU104 —
    /// closer to the workload ratio = better scalability.
    pub scalability: f64,
    /// Geometric-mean fraction of the adaptive policy's throughput across
    /// feasible devices.
    pub flexibility: f64,
}

/// Run the policy sweep that substantiates Table III.
pub fn assess_policies(clock_mhz: f64) -> Vec<PolicyAssessment> {
    let tiny = Model::lenet_tiny();
    let wide = Model::lenet_wide(2);
    let twelve = lenet_tiny_12bit();
    let devs = catalog();
    let adaptive = Policy::adaptive();
    // Adaptive throughput per device (the flexibility yardstick).
    let adaptive_tp: Vec<Option<f64>> =
        devs.iter().map(|d| plan(&tiny, d, clock_mhz, &adaptive).ok().map(|p| p.images_per_sec)).collect();

    baselines::all()
        .into_iter()
        .map(|pol| {
            let mut failed = 0;
            let mut ratios = Vec::new();
            for (d, atp) in devs.iter().zip(&adaptive_tp) {
                match plan(&tiny, d, clock_mhz, &pol) {
                    Ok(p) => {
                        if let Some(atp) = atp {
                            ratios.push((p.images_per_sec / atp).min(1.0));
                        }
                    }
                    Err(_) => failed += 1,
                }
            }
            let zcu = by_name("zcu104").unwrap();
            let scal = match (plan(&wide, &zcu, clock_mhz, &pol), plan(&tiny, &zcu, clock_mhz, &pol)) {
                (Ok(w), Ok(t)) => w.images_per_sec / t.images_per_sec,
                _ => 0.0,
            };
            let multi = plan(&twelve, &zcu, clock_mhz, &pol).is_ok();
            let flex = if ratios.is_empty() {
                0.0
            } else {
                (ratios.iter().map(|r| r.ln()).sum::<f64>() / ratios.len() as f64).exp()
                    * (ratios.len() as f64 / devs.len() as f64)
            };
            PolicyAssessment {
                policy: pol.name.clone(),
                failed_devices: failed,
                total_devices: devs.len(),
                multi_precision: multi,
                scalability: scal,
                flexibility: flex,
            }
        })
        .collect()
}

fn rate_dependency(a: &PolicyAssessment) -> &'static str {
    match a.failed_devices {
        0 => "Low",
        1 => "Medium",
        _ => "High",
    }
}

fn rate_scalability(a: &PolicyAssessment) -> &'static str {
    // The wide model has ~5.7x the tiny model's bottleneck work; retaining
    // >=1/3 of throughput means resources scaled with the model.
    if a.scalability >= 0.30 {
        "High"
    } else if a.scalability >= 0.15 {
        "Medium"
    } else {
        "Low"
    }
}

fn rate_flexibility(a: &PolicyAssessment) -> &'static str {
    if a.flexibility >= 0.85 {
        "High"
    } else if a.flexibility >= 0.5 {
        "Medium"
    } else {
        "Low"
    }
}

/// Table III — attribute comparison with ratings derived from
/// [`assess_policies`]. Columns map to the paper's: this work (adaptive)
/// vs the three related-work postures.
pub fn table3(clock_mhz: f64) -> Table {
    let assessments = assess_policies(clock_mhz);
    let mut t = Table::new(vec![
        "Attribute",
        "This Work (adaptive)",
        "dsp-first [4]-like",
        "quantize-first [5]-like",
        "static-single [1]-like",
    ]);
    let col = |f: &dyn Fn(&PolicyAssessment) -> String| -> Vec<String> {
        assessments.iter().map(|a| f(a)).collect()
    };
    let dep = col(&|a| rate_dependency(a).to_string());
    t.row(vec![
        "FPGA architecture dependency".to_string(),
        dep[0].clone(),
        dep[1].clone(),
        dep[2].clone(),
        dep[3].clone(),
    ]);
    let mp = col(&|a| if a.multi_precision { "Yes".into() } else { "No".into() });
    t.row(vec!["Multiple precisions".to_string(), mp[0].clone(), mp[1].clone(), mp[2].clone(), mp[3].clone()]);
    let sc = col(&|a| rate_scalability(a).to_string());
    t.row(vec!["Model scalability".to_string(), sc[0].clone(), sc[1].clone(), sc[2].clone(), sc[3].clone()]);
    let fl = col(&|a| rate_flexibility(a).to_string());
    t.row(vec!["Resource flexibility".to_string(), fl[0].clone(), fl[1].clone(), fl[2].clone(), fl[3].clone()]);
    t
}

/// Sweep-A: throughput (img/s) per device per policy. Uses the wide
/// model: lenet-tiny saturates its structural-parallelism caps on every
/// mid-size part and would make all devices look alike.
pub fn sweep_adaptation(clock_mhz: f64) -> Table {
    let m = Model::lenet_wide(4);
    let pols = baselines::all();
    let mut headers = vec!["device".to_string(), "DSPs".to_string(), "LUTs".to_string()];
    headers.extend(pols.iter().map(|p| p.name.clone()));
    let mut t = Table::new(headers).numeric();
    for dev in catalog() {
        let mut row = vec![dev.name.clone(), dev.dsps.to_string(), dev.luts.to_string()];
        for pol in &pols {
            row.push(match plan(&m, &dev, clock_mhz, pol) {
                Ok(p) => format!("{:.0}", p.images_per_sec),
                Err(_) => "infeasible".into(),
            });
        }
        t.row(row);
    }
    t
}

/// Sweep-B: operand width vs IP feasibility/resources (the Conv_3 8-bit
/// ceiling made visible).
pub fn sweep_precision(dev: &Device, clock_mhz: f64) -> Table {
    let mut t = Table::new(vec!["width", "IP", "LUTs", "Regs", "DSPs", "WNS (ns)", "lanes"]).numeric();
    for bits in [4u32, 6, 8, 10, 12, 16] {
        let params = ConvParams {
            k: 3,
            data_bits: bits,
            coef_bits: bits,
            out_bits: bits.min(16),
            shift: bits - 1,
            round: crate::fixed::Round::Truncate,
        };
        for kind in ConvKind::ALL {
            match ips::generate(kind, &params) {
                Ok(ip) => {
                    let u = synthesize(&ip.netlist);
                    let tm = sta::analyze(&ip.netlist, clock_mhz, dev.speed_derate).unwrap();
                    t.row(vec![
                        bits.to_string(),
                        kind.name().to_string(),
                        u.luts.to_string(),
                        u.regs.to_string(),
                        u.dsps.to_string(),
                        fnum(tm.wns_ns, 3),
                        kind.lanes().to_string(),
                    ]);
                }
                Err(_) => {
                    t.row(vec![
                        bits.to_string(),
                        kind.name().to_string(),
                        "—".into(),
                        "—".into(),
                        "—".into(),
                        "infeasible".into(),
                        "—".into(),
                    ]);
                }
            }
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_has_four_rows() {
        let t = table1();
        assert_eq!(t.n_rows(), 4);
        assert_eq!(t.cell(0, 0), "Conv_1");
        assert_eq!(t.cell(3, 1), "2 DSPs");
    }

    #[test]
    fn table2_shape_matches_paper() {
        let dev = by_name("zcu104").unwrap();
        let rows = table2_rows(&dev, 200.0);
        // Orderings from the paper (the reproduction contract — see
        // DESIGN.md: shape, not absolute numbers).
        let lut = |k: ConvKind| rows.iter().find(|r| r.kind == k).unwrap().luts;
        assert!(lut(ConvKind::Conv2) < lut(ConvKind::Conv4));
        assert!(lut(ConvKind::Conv4) <= lut(ConvKind::Conv3));
        assert!(lut(ConvKind::Conv3) < lut(ConvKind::Conv1));
        // All meet timing; Conv_3 tightest (§III.B).
        for r in &rows {
            assert!(r.wns_ns > 0.0, "{:?}", r.kind);
        }
        let wns = |k: ConvKind| rows.iter().find(|r| r.kind == k).unwrap().wns_ns;
        for k in [ConvKind::Conv1, ConvKind::Conv2, ConvKind::Conv4] {
            assert!(wns(ConvKind::Conv3) < wns(k));
        }
        // Power: static-dominated, Conv_4 highest.
        for r in &rows {
            assert!((0.593..0.60).contains(&r.power_w), "{:?} {}", r.kind, r.power_w);
        }
        assert!(wpow(&rows, ConvKind::Conv4) > wpow(&rows, ConvKind::Conv1));
    }

    fn wpow(rows: &[Table2Row], k: ConvKind) -> f64 {
        rows.iter().find(|r| r.kind == k).unwrap().power_w
    }

    #[test]
    fn table3_derivation_matches_paper_shape() {
        let a = assess_policies(200.0);
        assert_eq!(a[0].policy, "adaptive");
        // This work: low dependency, multi-precision, flexible.
        assert_eq!(a[0].failed_devices, 0, "adaptive must plan on every catalog device");
        assert!(a[0].multi_precision);
        assert!(a[0].flexibility > 0.99);
        // dsp-first fails somewhere and quantize-first lacks precision.
        let dsp = a.iter().find(|x| x.policy == "dsp-first").unwrap();
        assert!(dsp.failed_devices >= 1);
        let q = a.iter().find(|x| x.policy == "quantize-first").unwrap();
        assert!(!q.multi_precision);
    }

    #[test]
    fn opt_table_reports_per_engine_shrink() {
        let t = opt_table();
        // Conv_1..4, FC, MaxPool, ReLU — one row each.
        assert_eq!(t.n_rows(), 7);
        assert_eq!(t.cell(0, 0), "Conv_1");
        // Conv_1's counter buffers must fold: removals > 0 with at least
        // one pass credited for them.
        assert!(t.cell(0, 4).parse::<usize>().unwrap() > 0, "cells-: {}", t.cell(0, 4));
        assert_ne!(t.cell(0, 8), "none");
        let md = t.markdown();
        for needle in ["FC", "MaxPool", "ReLU", "->"] {
            assert!(md.contains(needle), "missing {needle} in:\n{md}");
        }
    }

    #[test]
    fn plan_table_lists_every_engine_kind() {
        let dev = by_name("zcu104").unwrap();
        let p = plan(&Model::lenet_tiny(), &dev, 200.0, &Policy::adaptive()).unwrap();
        let t = plan_table(&p);
        // 7 engine rows (conv+ReLU, pool, conv+ReLU, pool, FC) + totals.
        assert_eq!(t.n_rows(), 8);
        let md = t.markdown();
        for needle in ["MaxPool", "ReLU", "FC", "Conv_"] {
            assert!(md.contains(needle), "missing {needle} in:\n{md}");
        }
    }

    #[test]
    fn fleet_and_serve_tables_render() {
        let dev = by_name("zcu104").unwrap();
        let fp = crate::serve::FleetSpec::single(dev, Some(2))
            .plan()
            .model(&Model::lenet_tiny())
            .target_img_s(Some(1.0))
            .run()
            .unwrap();
        let t = fleet_table(&fp);
        // One device group plus the fleet totals row.
        assert_eq!(t.n_rows(), 2);
        assert_eq!(t.cell(0, 0), "zcu104");
        assert_eq!(t.cell(0, 1), "2");
        assert_eq!(t.cell(1, 0), "fleet");
        assert_eq!(t.cell(1, 1), "2");
        assert!(t.cell(1, 8).contains("yes"), "SLO cell: {}", t.cell(1, 8));
        // Coefficient BRAM shows up in the group's bill.
        assert!(t.cell(0, 6).starts_with(&fp.groups[0].total.bram18.to_string()));
        let m = crate::serve::FleetMetrics::new(2);
        m.note_dispatched(1, 4);
        m.note_replica_batch(1, 4, std::time::Duration::from_millis(2));
        let snap = m.snapshot();
        let t = serve_table(&snap);
        assert_eq!(t.n_rows(), 2);
        assert_eq!(t.cell(1, 1), "fleet");
        assert_eq!(t.cell(1, 2), "4");
        assert_eq!(t.cell(0, 4), "-");
        let t = serve_group_table(&snap);
        assert_eq!(t.n_rows(), 1);
        assert_eq!(t.cell(0, 0), "fleet");
        assert_eq!(t.cell(0, 1), "2");
        assert_eq!(t.cell(0, 2), "4");
        // No retirements: a clean "0/0" drain summary.
        assert_eq!(t.cell(0, 8), "0/0");
    }

    #[test]
    fn drain_summary_and_rebalance_timeline_render() {
        let m = crate::serve::FleetMetrics::new(2);
        m.note_drained(0);
        m.note_drain_timeout(0, 3);
        m.note_rebalance(crate::serve::RebalanceEvent {
            at_secs: 0.0,
            group: 0,
            label: "fleet".into(),
            action: crate::serve::RebalanceAction::Grow,
            from: 1,
            to: 2,
            reason: "queue 80% full".into(),
        });
        let snap = m.snapshot();
        let t = serve_group_table(&snap);
        assert_eq!(t.cell(0, 8), "1/1 (3 img stuck)");
        let t = rebalance_table(&snap.events);
        assert_eq!(t.n_rows(), 1);
        assert_eq!(t.cell(0, 1), "fleet");
        assert_eq!(t.cell(0, 2), "grow");
        assert_eq!(t.cell(0, 3), "1 -> 2");
        assert!(t.cell(0, 4).contains("queue"));
    }

    #[test]
    fn tenant_table_reports_quota_shed_and_slo() {
        use std::time::Duration;
        let m = crate::serve::FleetMetrics::new(1).with_tenants(vec![
            crate::serve::TenantInfo {
                name: "gold".into(),
                model: "lenet-tiny".into(),
                quota: 3.0,
                p99_slo_ms: Some(50.0),
            },
            crate::serve::TenantInfo {
                name: "bronze".into(),
                model: "lenet-wide-2x".into(),
                quota: 1.0,
                p99_slo_ms: None,
            },
        ]);
        m.note_accepted_t(0);
        m.note_completed_t(0, 0, Duration::from_millis(4));
        m.note_accepted_t(1);
        m.note_rejected_t(1);
        let snap = m.snapshot();
        let t = tenant_table(&snap);
        assert_eq!(t.n_rows(), 2);
        assert_eq!(t.cell(0, 0), "gold");
        assert_eq!(t.cell(0, 1), "lenet-tiny");
        assert_eq!(t.cell(0, 4), "0.0");
        assert!(t.cell(0, 9).contains("ok"), "SLO cell: {}", t.cell(0, 9));
        assert_eq!(t.cell(1, 0), "bronze");
        assert_eq!(t.cell(1, 4), "50.0");
        assert_eq!(t.cell(1, 9), "n/a");
    }

    #[test]
    fn scenario_tenant_table_renders_per_tenant_rows() {
        use crate::serve::scenario::{run_modeled, Scenario, ScenarioOpts, SimGroup};
        let sc = Scenario::from_str(
            r#"{"name":"mt","devices":"d","queue_depth":16,"recovery_tail":8,
                "tenants":[{"name":"gold","model":"m0","quota":3.0},
                           {"name":"bronze","model":"m0","quota":1.0}],
                "phases":[{"name":"rush","requests":200,
                           "load":{"profile":"constant","rate_x":2.0}}]}"#,
        )
        .unwrap();
        let groups =
            vec![SimGroup { label: "g".into(), replicas: 2, rate: 500.0, model: "m0".into() }];
        let r = run_modeled(&sc, &groups, 1000.0, &ScenarioOpts::default()).unwrap();
        let t = scenario_tenant_table(&r);
        assert_eq!(t.n_rows(), 2);
        assert_eq!(t.cell(0, 0), "rush");
        assert_eq!(t.cell(0, 1), "gold");
        assert_eq!(t.cell(0, 2), "m0");
        assert_eq!(t.cell(1, 1), "bronze");
        // Overload at 2x capacity: somebody got shed, and the small-quota
        // tenant at least as hard as the large one.
        let gold: f64 = t.cell(0, 5).parse().unwrap();
        let bronze: f64 = t.cell(1, 5).parse().unwrap();
        assert!(bronze >= gold, "gold {gold}% vs bronze {bronze}%");
    }

    #[test]
    fn heterogeneous_fleet_table_has_one_row_per_device() {
        let spec = crate::serve::FleetSpec {
            entries: vec![
                crate::serve::FleetEntry { device: by_name("zcu104").unwrap(), count: Some(1) },
                crate::serve::FleetEntry { device: by_name("zu5ev").unwrap(), count: Some(1) },
            ],
        };
        let fp = spec.plan().model(&Model::lenet_tiny()).max_replicas(2).run().unwrap();
        let t = fleet_table(&fp);
        assert_eq!(t.n_rows(), 3);
        assert_eq!(t.cell(0, 0), "zcu104");
        assert_eq!(t.cell(1, 0), "zu5ev");
        assert_eq!(t.cell(2, 0), "fleet");
        assert_eq!(t.cell(2, 1), "2");
        assert_eq!(t.cell(2, 8), "n/a");
    }

    #[test]
    fn scenario_and_fault_tables_render() {
        use crate::serve::scenario::{run_modeled, Scenario, ScenarioOpts, SimGroup};
        let sc = Scenario::from_str(
            r#"{"name":"x","devices":"d","queue_depth":64,"recovery_tail":16,"phases":[
                {"name":"steady","requests":300,
                 "load":{"profile":"constant","rate_x":0.35},
                 "faults":[{"at_frac":0.5,"kind":"replica_death","group":0}],
                 "asserts":{"max_shed_pct":10.0,"recovery_ms_max":60000.0}}]}"#,
        )
        .unwrap();
        let groups =
            vec![SimGroup { label: "g".into(), replicas: 2, rate: 1000.0, model: String::new() }];
        let r = run_modeled(&sc, &groups, 2000.0, &ScenarioOpts::default()).unwrap();
        let t = scenario_table(&r);
        assert_eq!(t.n_rows(), 1);
        assert_eq!(t.cell(0, 0), "steady");
        assert_eq!(t.cell(0, 8), if r.phases[0].passed { "PASS" } else { "FAIL" });
        assert!(t.cell(0, 7).contains("max_shed_pct"), "checks cell: {}", t.cell(0, 7));
        let t = fault_timeline_table(&r.faults);
        assert_eq!(t.n_rows(), 1);
        assert_eq!(t.cell(0, 2), "replica_death");
        assert!(t.cell(0, 5).ends_with("ms") || t.cell(0, 5) == "never");
    }

    #[test]
    fn trace_summary_renders_stages_in_pipeline_order() {
        use crate::trace::{stage_summary, EventKind, TraceEvent, PID_REQUESTS};
        let span = |name: &'static str, dur: u64| TraceEvent {
            name: name.to_string(),
            cat: "request",
            kind: EventKind::Span,
            ts_nanos: 0,
            dur_nanos: dur,
            pid: PID_REQUESTS,
            tid: 1,
            args: Vec::new(),
        };
        let events = vec![
            span("reply", 2_000_000),
            span("admit", 1_000_000),
            span("admit", 3_000_000),
        ];
        let t = trace_summary(&stage_summary(&events));
        assert_eq!(t.n_rows(), 2);
        // Pipeline order, not event order: admit before reply.
        assert_eq!(t.cell(0, 0), "admit");
        assert_eq!(t.cell(0, 1), "2");
        assert_eq!(t.cell(0, 2), "2.000");
        assert_eq!(t.cell(1, 0), "reply");
        assert_eq!(t.cell(1, 3), "2.000");
    }

    #[test]
    fn sweeps_render() {
        let dev = by_name("zcu104").unwrap();
        let s = sweep_precision(&dev, 200.0);
        assert!(s.n_rows() >= 24);
        let md = s.markdown();
        assert!(md.contains("infeasible"), "Conv_3 ceiling must be visible:\n{md}");
    }
}
