//! `acf` — the adaptive-conv-FPGA command line.
//!
//! Subcommands:
//!   tables   — regenerate the paper's Tables I/II/III (+ the netlist
//!              optimizer's per-engine shrink report via --table opt)
//!   synth    — synthesize one IP and print its utilization
//!   sta      — timing report (+ critical path trace) for one IP
//!   power    — power report for one IP
//!   plan        — resource-driven deployment plan for a model on a device
//!   deploy      — plan + run a batch of synthetic images (behavioral fabric)
//!   serve       — plan a replica fleet and drive it with open-loop traffic
//!                 (--models m1:t1,m2:t2 serves a model zoo to a tenant
//!                 roster with quota-weighted admission; --serve-config FILE
//!                 loads the admission/dispatch/tenant sections from JSON;
//!                 --rebalance adds the live controller under a step load;
//!                 --trace FILE exports the run's Chrome trace-event timeline;
//!                 --scenario FILE runs a deterministic fault-injection
//!                 scenario against the modeled fleet instead)
//!   scenario-check — run every scenario JSON in a directory and write
//!                 per-scenario verdict files (CI gate; quick mode via
//!                 ACF_BENCH_QUICK=1)
//!   sweep       — adaptation / precision sweeps
//!   golden      — run the AOT XLA artifact and cross-check vs behavioral
//!   bench-check — gate fresh BENCH_*.json series against BENCH_baseline/
//!   trace-check — validate a Chrome trace-event JSON file (CI gate)
//!   version     — print version

use acf::cnn::data::Dataset;
use acf::cnn::model::Model;
use acf::fabric::device;
use acf::ips::{self, ConvKind, ConvParams};
use acf::planner::{baselines, Policy};
use acf::util::cli::{help, Args, OptSpec};
use acf::util::table::fnum;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let code = match argv.first().map(|s| s.as_str()) {
        Some("tables") => cmd_tables(&argv[1..]),
        Some("synth") => cmd_ip(&argv[1..], Mode::Synth),
        Some("sta") => cmd_ip(&argv[1..], Mode::Sta),
        Some("power") => cmd_ip(&argv[1..], Mode::Power),
        Some("plan") => cmd_plan(&argv[1..], false),
        Some("deploy") => cmd_plan(&argv[1..], true),
        Some("serve") => cmd_serve(&argv[1..]),
        Some("scenario-check") => cmd_scenario_check(&argv[1..]),
        Some("sweep") => cmd_sweep(&argv[1..]),
        Some("golden") => cmd_golden(&argv[1..]),
        Some("bench-check") => cmd_bench_check(&argv[1..]),
        Some("trace-check") => cmd_trace_check(&argv[1..]),
        Some("version") => {
            println!("acf {}", acf::VERSION);
            0
        }
        _ => {
            eprintln!(
                "usage: acf <tables|synth|sta|power|plan|deploy|serve|scenario-check|sweep|golden|bench-check|trace-check|version> [options]\n\
                 run `acf <cmd> --help` for per-command options"
            );
            2
        }
    };
    std::process::exit(code);
}

enum Mode {
    Synth,
    Sta,
    Power,
}

fn dev_specs() -> Vec<OptSpec> {
    vec![
        OptSpec { name: "device", value: true, help: "device name/part", default: Some("zcu104") },
        OptSpec { name: "clock-mhz", value: true, help: "target clock", default: Some("200") },
        opt_level_spec(),
        OptSpec { name: "help", value: false, help: "show help", default: None },
    ]
}

fn opt_level_spec() -> OptSpec {
    OptSpec {
        name: "opt-level",
        value: true,
        help: "netlist optimization level 0|1|2 (auto = ACF_OPT_LEVEL, default full opt)",
        default: Some("auto"),
    }
}

/// Resolve `--opt-level` into the process-wide netlist-opt level.
/// `auto` keeps the `ACF_OPT_LEVEL` env default.
fn apply_opt_level(a: &Args) -> Result<(), String> {
    match a.get_or("opt-level", "auto") {
        "auto" => Ok(()),
        s => match acf::netlist::opt::OptLevel::parse(s) {
            Some(l) => {
                acf::netlist::opt::set_level(l);
                Ok(())
            }
            None => Err(format!("bad --opt-level '{s}' (want 0|1|2|auto)")),
        },
    }
}

fn get_device(a: &Args) -> Result<device::Device, String> {
    let name = a.get_or("device", "zcu104");
    device::by_name(name).ok_or_else(|| format!("unknown device '{name}'"))
}

fn cmd_tables(argv: &[String]) -> i32 {
    let mut specs = dev_specs();
    specs.push(OptSpec { name: "table", value: true, help: "1|2|3|opt|all", default: Some("all") });
    let a = match Args::parse(argv, &specs) {
        Ok(a) => a,
        Err(e) => return fail(e),
    };
    if a.flag("help") {
        print!("{}", help("acf tables", "regenerate the paper's tables", &specs));
        return 0;
    }
    if let Err(e) = apply_opt_level(&a) {
        return fail(e);
    }
    let dev = match get_device(&a) {
        Ok(d) => d,
        Err(e) => return fail(e),
    };
    let clock = a.get_f64("clock-mhz").unwrap().unwrap();
    let which = a.get_or("table", "all");
    if which == "1" || which == "all" {
        println!("\nTABLE I — CHARACTERISTICS OF DEVELOPED CONVOLUTION IPS\n{}", acf::report::table1().markdown());
    }
    if which == "2" || which == "all" {
        println!(
            "\nTABLE II — RESOURCE UTILIZATION (measured on simulated {}, {} MHz | paper reference)\n{}",
            dev.name,
            clock,
            acf::report::table2(&dev, clock).markdown()
        );
    }
    if which == "3" || which == "all" {
        println!(
            "\nTABLE III — COMPARISON OF OPTIMIZATION TECHNIQUES (ratings derived from policy sweeps)\n{}",
            acf::report::table3(clock).markdown()
        );
    }
    if which == "opt" || which == "all" {
        println!(
            "\nNETLIST OPTIMIZATION PASS PIPELINE — per-engine pre -> post primitives at O2\n{}",
            acf::report::opt_table().markdown()
        );
    }
    0
}

fn cmd_ip(argv: &[String], mode: Mode) -> i32 {
    let mut specs = dev_specs();
    specs.push(OptSpec { name: "ip", value: true, help: "conv1..conv4", default: Some("conv2") });
    specs.push(OptSpec { name: "bits", value: true, help: "operand width", default: Some("8") });
    specs.push(OptSpec { name: "k", value: true, help: "kernel size", default: Some("3") });
    let a = match Args::parse(argv, &specs) {
        Ok(a) => a,
        Err(e) => return fail(e),
    };
    if a.flag("help") {
        print!("{}", help("acf synth/sta/power", "per-IP reports", &specs));
        return 0;
    }
    if let Err(e) = apply_opt_level(&a) {
        return fail(e);
    }
    let dev = match get_device(&a) {
        Ok(d) => d,
        Err(e) => return fail(e),
    };
    let clock = a.get_f64("clock-mhz").unwrap().unwrap();
    let kind = match ConvKind::parse(a.get_or("ip", "conv2")) {
        Some(k) => k,
        None => return fail("bad --ip (want conv1..conv4)"),
    };
    let bits = a.get_u64("bits").unwrap().unwrap() as u32;
    let k = a.get_u64("k").unwrap().unwrap() as u32;
    let params = ConvParams {
        k,
        data_bits: bits,
        coef_bits: bits,
        out_bits: bits.min(16),
        shift: bits - 1,
        round: acf::fixed::Round::Truncate,
    };
    let ip = match ips::generate(kind, &params) {
        Ok(ip) => ip,
        Err(e) => return fail(e),
    };
    let u = acf::synth::synthesize(&ip.netlist);
    match mode {
        Mode::Synth => {
            println!(
                "{} ({bits}-bit, {k}x{k}): LUTs={} Regs={} CARRY8={} CLBs={} DSPs={} BRAM18={}",
                kind.name(),
                u.luts,
                u.regs,
                u.carry8,
                u.clbs,
                u.dsps,
                u.bram18
            );
        }
        Mode::Sta => {
            let t = acf::sta::analyze(&ip.netlist, clock, dev.speed_derate).unwrap();
            println!(
                "{}: period {:.3} ns | critical path {:.3} ns | WNS {:.3} ns | fmax {:.1} MHz | endpoint {}",
                kind.name(),
                t.period_ns,
                t.critical_path_ns,
                t.wns_ns,
                t.fmax_mhz(),
                t.endpoint
            );
            for (desc, at) in acf::sta::trace_critical(&ip.netlist, clock, dev.speed_derate) {
                println!("  {:>7}  {}", fnum(at, 3), desc);
            }
        }
        Mode::Power => {
            let p = acf::power::estimate(&u, &dev, clock, None);
            println!(
                "{} on {}: static {:.3} W + clock {:.4} W + dynamic {:.4} W = {:.3} W",
                kind.name(),
                dev.name,
                p.static_w,
                p.clock_w,
                p.dynamic_w,
                p.total_w()
            );
        }
    }
    0
}

fn model_by_name(name: &str) -> Result<Model, String> {
    if let Some(m) = acf::cnn::model::model_by_name(name) {
        return Ok(m);
    }
    match name {
        "lenet-12bit" => Ok(acf::report::lenet_tiny_12bit()),
        path => {
            let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
            let json = acf::util::json::Json::parse(&text).map_err(|e| e.to_string())?;
            Model::from_json(&json).map_err(|e| e.to_string())
        }
    }
}

/// Parse `--models model:tenant[:quota],...` into one tenant spec per
/// entry (`'none'` -> `None`; quota defaults to 1). Model names stay as
/// written — the zoo loop resolves and canonicalizes them so registry
/// shorthands and model files both work.
fn parse_models_flag(list: &str) -> Result<Option<Vec<acf::serve::TenantSpec>>, String> {
    if list == "none" {
        return Ok(None);
    }
    let mut tenants: Vec<acf::serve::TenantSpec> = Vec::new();
    for entry in list.split(',') {
        let parts: Vec<&str> = entry.split(':').collect();
        if !(2..=3).contains(&parts.len()) || parts[0].is_empty() || parts[1].is_empty() {
            return Err(format!("--models entry '{entry}': want model:tenant[:quota]"));
        }
        let quota = match parts.get(2) {
            Some(q) => q
                .parse::<f64>()
                .ok()
                .filter(|q| *q > 0.0)
                .ok_or_else(|| {
                    format!("--models entry '{entry}': quota must be a positive number")
                })?,
            None => 1.0,
        };
        if tenants.iter().any(|t| t.name == parts[1]) {
            return Err(format!("--models: duplicate tenant '{}'", parts[1]));
        }
        tenants.push(acf::serve::TenantSpec {
            name: parts[1].to_string(),
            model: parts[0].to_string(),
            quota,
            p99_slo_ms: None,
        });
    }
    if tenants.is_empty() {
        return Err("--models: empty list".into());
    }
    Ok(Some(tenants))
}

fn parse_model(a: &Args) -> Result<Model, String> {
    model_by_name(a.get_or("model", "lenet-tiny"))
}

fn parse_policy(a: &Args) -> Result<Policy, String> {
    match a.get_or("policy", "adaptive") {
        "adaptive" => Ok(Policy::adaptive()),
        "dsp-first" => Ok(baselines::dsp_first()),
        "quantize-first" => Ok(baselines::quantize_first()),
        "static-single" => Ok(baselines::static_single()),
        other => Err(format!("unknown policy '{other}'")),
    }
}

fn cmd_plan(argv: &[String], deploy: bool) -> i32 {
    let mut specs = dev_specs();
    specs.push(OptSpec {
        name: "model",
        value: true,
        help: "lenet-tiny|lenet-wide2|lenet-wide4|lenet-12bit|<file.json>",
        default: Some("lenet-tiny"),
    });
    specs.push(OptSpec { name: "policy", value: true, help: "adaptive|dsp-first|quantize-first|static-single", default: Some("adaptive") });
    specs.push(OptSpec { name: "images", value: true, help: "batch size (deploy)", default: Some("32") });
    specs.push(OptSpec { name: "seed", value: true, help: "weights/data seed", default: Some("42") });
    let a = match Args::parse(argv, &specs) {
        Ok(a) => a,
        Err(e) => return fail(e),
    };
    if a.flag("help") {
        print!("{}", help("acf plan/deploy", "resource-driven planning + batch inference", &specs));
        return 0;
    }
    if let Err(e) = apply_opt_level(&a) {
        return fail(e);
    }
    let dev = match get_device(&a) {
        Ok(d) => d,
        Err(e) => return fail(e),
    };
    let clock = a.get_f64("clock-mhz").unwrap().unwrap();
    let model = match parse_model(&a) {
        Ok(m) => m,
        Err(e) => return fail(e),
    };
    let policy = match parse_policy(&a) {
        Ok(p) => p,
        Err(e) => return fail(e),
    };
    let plan = match acf::planner::plan(&model, &dev, clock, &policy) {
        Ok(p) => p,
        Err(e) => return fail(e),
    };
    println!("plan for '{}' on {} @ {} MHz (policy {}):", model.name, dev.name, clock, plan.policy);
    print!("{}", acf::report::plan_table(&plan).plain());
    let (pd, pl) = plan.pressure();
    println!(
        "  total: LUT {}/{} ({:.1}%)  DSP {}/{} ({:.1}%)  CLB {}  modeled {:.0} img/s (bottleneck layer {})",
        plan.total.luts,
        dev.luts,
        pl * 100.0,
        plan.total.dsps,
        dev.dsps,
        pd * 100.0,
        plan.total.clbs,
        plan.images_per_sec,
        plan.bottleneck
    );
    let perf = acf::sim::estimate(&model, &plan);
    println!("  latency (single image): {:.1} µs", perf.latency_us);

    if deploy {
        let n = a.get_usize("images").unwrap().unwrap();
        let seed = a.get_u64("seed").unwrap().unwrap();
        let weights = acf::cnn::model::Weights::random(&model, seed);
        let dep = match acf::coordinator::Deployment::new(model.clone(), weights.clone(), &dev, clock, &policy) {
            Ok(d) => d,
            Err(e) => return fail(e),
        };
        let ds = Dataset::generate(n, seed, model.in_h, model.in_w);
        let images: Vec<Vec<i64>> = ds.images.iter().map(|i| i.pix.clone()).collect();
        let out = match dep.infer_batch(&images) {
            Ok(o) => o,
            Err(e) => return fail(e),
        };
        let mismatches = images
            .iter()
            .zip(&out)
            .filter(|(img, o)| &acf::cnn::infer::infer(&dep.model, &weights, img) != *o)
            .count();
        let snap = dep.metrics.snapshot();
        println!(
            "deployed batch: {} images in {:.3} s ({:.0} img/s host) — {} reference mismatches",
            snap.images,
            snap.wall_secs,
            snap.throughput(),
            mismatches
        );
        // Modeled (engine plan) vs measured (worker wall time) per layer —
        // both keyed by the same layer index.
        for (li, (cyc, secs)) in dep.layer_cycles().iter().zip(&snap.layer_secs).enumerate() {
            println!("  layer {li}: modeled {cyc:.0} cyc/img | measured {:.2} ms host", secs * 1e3);
        }
        if let Some(h) = snap.hottest_layer() {
            println!("  hottest measured layer: {h} (modeled bottleneck: {})", plan.bottleneck);
        }
        if mismatches > 0 {
            return 1;
        }
    }
    0
}

fn cmd_serve(argv: &[String]) -> i32 {
    let mut specs = dev_specs();
    specs.push(OptSpec {
        name: "model",
        value: true,
        help: "lenet-tiny|lenet-wide2|lenet-wide4|lenet-12bit|<file.json>",
        default: Some("lenet-tiny"),
    });
    specs.push(OptSpec {
        name: "models",
        value: true,
        help: "multi-tenant zoo: model:tenant[:quota],... (e.g. lenet-tiny:acme:3,lenet-wide2:beta) — each tenant routes to its model under quota-weighted admission, or 'none'",
        default: Some("none"),
    });
    specs.push(OptSpec {
        name: "serve-config",
        value: true,
        help: "ServeConfig JSON file (admission/dispatch/tenants sections; overrides --queue-depth/--max-batch/--drain-deadline-ms), or 'none'",
        default: Some("none"),
    });
    specs.push(OptSpec { name: "policy", value: true, help: "adaptive|dsp-first|quantize-first|static-single", default: Some("adaptive") });
    specs.push(OptSpec {
        name: "devices",
        value: true,
        help: "heterogeneous fleet: name[:count],... (e.g. zcu104,zu5ev:2; overrides --device/--replicas), or 'auto'",
        default: Some("auto"),
    });
    specs.push(OptSpec { name: "catalog", value: true, help: "JSON device-array file extending --devices lookups, or 'none'", default: Some("none") });
    specs.push(OptSpec { name: "replicas", value: true, help: "replica count (single-device mode), or 'auto' to search", default: Some("auto") });
    specs.push(OptSpec { name: "max-replicas", value: true, help: "per-device ceiling for the replica search", default: Some("8") });
    specs.push(OptSpec { name: "target-img-s", value: true, help: "throughput SLO (modeled; picks the cheapest static-power mix), or 'none'", default: Some("none") });
    specs.push(OptSpec { name: "requests", value: true, help: "open-loop request count", default: Some("512") });
    specs.push(OptSpec { name: "offered-img-s", value: true, help: "open-loop arrival rate, or 'auto' (calibrated)", default: Some("auto") });
    specs.push(OptSpec { name: "max-batch", value: true, help: "micro-batch ceiling per dispatch (clamped per replica by modeled rate)", default: Some("8") });
    specs.push(OptSpec { name: "queue-depth", value: true, help: "bounded submission queue depth", default: Some("64") });
    specs.push(OptSpec { name: "seed", value: true, help: "weights/data/arrivals seed", default: Some("42") });
    specs.push(OptSpec { name: "rebalance", value: false, help: "enable the live rebalancer and drive a low->spike->low step load", default: None });
    specs.push(OptSpec { name: "window-ms", value: true, help: "rebalance control period / signal window", default: Some("250") });
    specs.push(OptSpec { name: "headroom", value: true, help: "capacity headroom the rebalancer keeps (scale-up watermark = 1 - headroom)", default: Some("0.25") });
    specs.push(OptSpec { name: "cooldown-ms", value: true, help: "quiet time between rebalance actions, or 'auto' (2x window)", default: Some("auto") });
    specs.push(OptSpec { name: "drain-deadline-ms", value: true, help: "how long a retiring replica gets to drain before being reported late", default: Some("5000") });
    specs.push(OptSpec { name: "trace", value: true, help: "write the run's span timeline (admission to settle) as Chrome trace-event JSON to this file (open in chrome://tracing or Perfetto), or 'none'", default: Some("none") });
    specs.push(OptSpec { name: "scenario", value: true, help: "run a deterministic fault-injection scenario JSON against the modeled fleet instead of live traffic (exit code = verdict), or 'none'", default: Some("none") });
    specs.push(OptSpec { name: "verdict", value: true, help: "with --scenario: also write the verdict report JSON to this file, or 'none'", default: Some("none") });
    let a = match Args::parse(argv, &specs) {
        Ok(a) => a,
        Err(e) => return fail(e),
    };
    if a.flag("help") {
        print!("{}", help("acf serve", "device-fleet serving under synthetic open-loop traffic", &specs));
        return 0;
    }
    if let Err(e) = apply_opt_level(&a) {
        return fail(e);
    }
    let clock = a.get_f64("clock-mhz").unwrap().unwrap();
    let scenario_path = a.get_or("scenario", "none");
    if scenario_path != "none" {
        // Scenario mode: the file names its own model/fleet; everything
        // else (catalog, policy, seed, trace) comes from the flags.
        return cmd_serve_scenario(&a, scenario_path, clock);
    }
    let model = match parse_model(&a) {
        Ok(m) => m,
        Err(e) => return fail(e),
    };
    let policy = match parse_policy(&a) {
        Ok(p) => p,
        Err(e) => return fail(e),
    };
    let forced = match a.get_u64_auto("replicas") {
        Ok(v) => v,
        Err(e) => return fail(e),
    };
    let max_replicas = a.get_u64("max-replicas").unwrap().unwrap() as usize;
    let target = match a.get_f64_auto("target-img-s") {
        Ok(v) => v,
        Err(e) => return fail(e),
    };
    let requests = a.get_usize("requests").unwrap().unwrap();
    let seed = a.get_u64("seed").unwrap().unwrap();
    let drain_deadline = match a.get_ms("drain-deadline-ms") {
        Ok(d) => d.unwrap(),
        Err(e) => return fail(e),
    };
    // One clock for the whole run: the server's metrics/spans and the
    // CLI-side settle-attribution spans must share a timeline.
    let trace_path = match a.get_or("trace", "none") {
        "none" => None,
        p => Some(p.to_string()),
    };
    let wall = acf::trace::Clock::wall();
    let tracer = if trace_path.is_some() {
        acf::trace::Tracer::ring(acf::trace::RingSink::DEFAULT_CAP)
    } else {
        acf::trace::Tracer::off()
    };
    let mut cfg = match a.get_or("serve-config", "none") {
        "none" => {
            let mut c = acf::serve::ServeConfig::sized(
                a.get_usize("queue-depth").unwrap().unwrap(),
                a.get_usize("max-batch").unwrap().unwrap(),
            );
            c.dispatch.drain_deadline = drain_deadline;
            c
        }
        path => {
            let parsed = std::fs::read_to_string(path)
                .map_err(|e| format!("{path}: {e}"))
                .and_then(|text| {
                    acf::util::json::Json::parse(&text).map_err(|e| format!("{path}: {e}"))
                })
                .and_then(|json| {
                    acf::serve::ServeConfig::from_json(&json).map_err(|e| format!("{path}: {e}"))
                });
            match parsed {
                Ok(c) => c,
                Err(e) => return fail(e),
            }
        }
    };
    cfg.clock = wall.clone();
    cfg.tracer = tracer.clone();
    // --models wins over the config file's tenants section.
    match parse_models_flag(a.get_or("models", "none")) {
        Ok(Some(tenants)) => cfg.tenants = acf::serve::TenantConfig { tenants },
        Ok(None) => {}
        Err(e) => return fail(e),
    }
    // Canonicalize tenant model names (registry shorthands, empty string
    // = the --model default) and collect the zoo the fleet must carry.
    let mut zoo: Vec<Model> = Vec::new();
    if cfg.tenants.tenants.is_empty() {
        zoo.push(model.clone());
    } else {
        for t in &mut cfg.tenants.tenants {
            let m = if t.model.is_empty() {
                model.clone()
            } else {
                match model_by_name(&t.model) {
                    Ok(m) => m,
                    Err(e) => return fail(format!("tenant '{}': {e}", t.name)),
                }
            };
            t.model = m.name.clone();
            if !zoo.iter().any(|z| z.name == m.name) {
                zoo.push(m);
            }
        }
    }
    if zoo.iter().any(|m| m.in_ch != 1) {
        return fail("the synthetic load corpus is single-channel; serve needs in_ch == 1");
    }
    let multi = !cfg.tenants.tenants.is_empty();
    let rebalance = a.flag("rebalance");
    let window = match a.get_ms("window-ms") {
        Ok(w) => w.unwrap(),
        Err(e) => return fail(e),
    };
    let headroom = match a.get_f64("headroom") {
        Ok(h) => h.unwrap(),
        Err(e) => return fail(e),
    };
    let cooldown = match a.get_ms_auto("cooldown-ms") {
        Ok(c) => c.unwrap_or(2 * window),
        Err(e) => return fail(e),
    };

    // 1. Fleet spec: either the single --device (PR 2 surface, with
    //    --replicas as the forced count) or a heterogeneous --devices
    //    list. Both resolve names against the --catalog JSON file first,
    //    then the built-in catalog.
    let extra = match load_extra_catalog(&a) {
        Ok(devs) => devs,
        Err(e) => return fail(e),
    };
    let fleet_spec = match a.get_or("devices", "auto") {
        "auto" | "none" => match acf::serve::FleetSpec::parse(a.get_or("device", "zcu104"), &extra)
        {
            Ok(mut s) => {
                s.entries[0].count = forced.map(|r| r as usize);
                s
            }
            Err(e) => return fail(e),
        },
        list => match acf::serve::FleetSpec::parse(list, &extra) {
            Ok(s) => s,
            Err(e) => return fail(e),
        },
    };

    // 2. Fleet plan: per-device replica frontiers composed across the
    //    catalog (throughput-argmax, or cheapest static power under the
    //    target SLO). The frontier is kept — it is what the live
    //    rebalancer indexes instead of ever re-running the planner.
    let zoo_arcs: Vec<std::sync::Arc<Model>> =
        zoo.iter().map(|m| std::sync::Arc::new(m.clone())).collect();
    let frontier = match acf::serve::FleetFrontier::build_zoo(
        zoo_arcs,
        &fleet_spec,
        clock,
        &policy,
        max_replicas,
    ) {
        Ok(fr) => fr,
        Err(e) => return fail(e),
    };
    let fp = acf::serve::compose_frontier(&frontier, target);
    if multi {
        // Composition covers every model it can; a tenant whose model
        // still lost out needs more hardware, not a panic downstream.
        for t in &cfg.tenants.tenants {
            if !fp.groups.iter().any(|g| fp.models[g.model_id].name == t.model) {
                return fail(format!(
                    "tenant '{}' routes to model '{}' but no device group carries it — list at least one device per model (--devices)",
                    t.name, t.model
                ));
            }
        }
    }
    let zoo_names = zoo.iter().map(|m| m.name.clone()).collect::<Vec<_>>().join(" + ");
    println!(
        "fleet plan for '{}' @ {} MHz (policy {}): {} device group(s), {} replica(s)",
        zoo_names,
        clock,
        policy.name,
        fp.groups.len(),
        fp.replicas()
    );
    print!("{}", acf::report::fleet_table(&fp).plain());
    for g in &fp.groups {
        println!(
            "{} engine plan for '{}' (each of {} replica(s) owns a 1/{} shard; {} RAMB18 coefficient store per replica):",
            g.device.name,
            fp.models[g.model_id].name,
            g.replicas,
            g.replicas,
            g.coef_bram18
        );
        print!("{}", acf::report::plan_table(&g.per_replica).plain());
    }
    if !fp.meets_target {
        println!(
            "warning: no mix up to {max_replicas} replicas/device meets the {:.0} img/s target; serving best effort",
            fp.target_img_s.unwrap_or(0.0)
        );
    }

    // 3. Deploy the fleet and precompute the corpus + reference logits
    //    (once per distinct image — responses are checked against these).
    //    Model/weights stay behind shared handles so rebalance-spawned
    //    replicas reuse the same allocations.
    let weights_arcs: Vec<std::sync::Arc<acf::cnn::model::Weights>> = zoo
        .iter()
        .map(|m| std::sync::Arc::new(acf::cnn::model::Weights::random(m, seed)))
        .collect();
    let fleet = fp.deploy_zoo(&weights_arcs);
    let replica_groups = fp.replica_groups();
    let corpus_n = requests.clamp(8, 64);
    let corpora: Vec<Vec<Vec<i64>>> = zoo
        .iter()
        .map(|m| {
            Dataset::generate(corpus_n, seed, m.in_h, m.in_w)
                .images
                .iter()
                .map(|i| i.pix.clone())
                .collect()
        })
        .collect();
    // references[model][image]: the behavioral logits every serving path
    // must reproduce bit-exactly.
    let references: Vec<Vec<Vec<i64>>> = zoo
        .iter()
        .zip(&corpora)
        .zip(&weights_arcs)
        .map(|((m, corpus), w)| {
            corpus.iter().map(|img| acf::cnn::infer::infer(m, w, img)).collect()
        })
        .collect();

    // 4. Calibrate host throughput per device group (the honest basis for
    //    a measured replica-sum: the FPGA-clock model is not host time).
    //    Runs through the one-shot path, before any server exists.
    let mut group_img_s_host = vec![0.0f64; fp.groups.len()];
    for (ri, dep) in fleet.replicas.iter().enumerate() {
        let gi = replica_groups[ri];
        if group_img_s_host[gi] > 0.0 {
            continue; // one calibration per group — replicas within a group are identical
        }
        let corpus = &corpora[fp.groups[gi].model_id];
        let cal_images: Vec<Vec<i64>> =
            (0..64).map(|i| corpus[i % corpus.len()].clone()).collect();
        let t0 = std::time::Instant::now();
        dep.infer_batch(&cal_images).expect("calibration batch");
        group_img_s_host[gi] = cal_images.len() as f64 / t0.elapsed().as_secs_f64().max(1e-9);
    }
    let replica_sum_host: f64 = fp
        .groups
        .iter()
        .zip(&group_img_s_host)
        .map(|(g, &img_s)| img_s * g.replicas as f64)
        .sum();
    let offered = match a.get_f64_auto("offered-img-s") {
        Ok(Some(r)) => r,
        // Auto: offer ~90% of the calibrated host replica-sum so a healthy
        // fleet keeps up and overload stays an explicit choice.
        Ok(None) => (replica_sum_host * 0.9).max(1.0),
        Err(e) => return fail(e),
    };

    // 5. Bit-exactness: the serving path must produce exactly what every
    //    group's one-shot infer_batch path (and the behavioral reference)
    //    does — different per-device plans, identical logits. Uses a
    //    throwaway server over the same replicas so the load run's fleet
    //    metrics stay untouched.
    let sample_len = corpus_n.min(8);
    let mut mismatches = 0usize;
    for (ri, dep) in fleet.replicas.iter().enumerate() {
        if replica_groups[..ri].contains(&replica_groups[ri]) {
            continue; // first replica of each group carries its plan
        }
        let mi = fp.groups[replica_groups[ri]].model_id;
        let batch =
            dep.infer_batch(&corpora[mi][..sample_len]).expect("replica serves the sample");
        mismatches += references[mi][..sample_len]
            .iter()
            .zip(&batch)
            .filter(|(reference, b)| b != reference)
            .count();
    }
    {
        // The warmup server's request ids restart at 1 — trace it and its
        // spans would collide with the load run's request tracks.
        let warmup_cfg =
            acf::serve::ServeConfig { tracer: acf::trace::Tracer::off(), ..cfg.clone() };
        let warmup = acf::serve::Server::start(fleet.clone(), &warmup_cfg);
        for t in 0..warmup.n_tenants() {
            let mname = warmup.model_of_tenant(t).name.clone();
            let mi = zoo.iter().position(|m| m.name == mname).unwrap_or(0);
            let pendings: Vec<_> = corpora[mi][..sample_len]
                .iter()
                .map(|img| warmup.submit_wait_as(t, img.clone()).expect("server accepting"))
                .collect();
            let served: Vec<Vec<i64>> =
                pendings.into_iter().map(|p| p.wait().expect("request served")).collect();
            mismatches += references[mi][..sample_len]
                .iter()
                .zip(&served)
                .filter(|(reference, s)| s != reference)
                .count();
        }
        drop(warmup.shutdown());
    }
    println!(
        "serving-path check: {} mismatches across {} device group(s) x {} sample images (scheduled + one-shot vs behavioral reference)",
        mismatches,
        fp.groups.len(),
        sample_len
    );

    // 6. Open-loop load against a fresh server (clean metrics clock).
    //    With --rebalance the profile is a low -> spike -> low step load
    //    and the live controller resizes device groups underneath it.
    let server = std::sync::Arc::new(acf::serve::Server::start(fleet, &cfg));
    // Tenant -> zoo-model index (tenant 0 of an untenanted fleet is the
    // implicit default route).
    let tenant_mi: Vec<usize> = (0..server.n_tenants())
        .map(|t| {
            let name = &server.model_of_tenant(t).name;
            zoo.iter().position(|m| &m.name == name).unwrap_or(0)
        })
        .collect();
    let rb = if rebalance {
        if fleet_spec.entries.iter().all(|e| e.count.is_some()) {
            println!(
                "warning: every device group has a forced count (--replicas / name:count) — \
                 the rebalancer never resizes pinned groups, so it will observe but not act"
            );
        }
        Some(acf::serve::Rebalancer::start(
            std::sync::Arc::clone(&server),
            frontier.clone(),
            &fp,
            weights_arcs.clone(),
            acf::serve::RebalanceConfig {
                window,
                headroom,
                cooldown,
                ..acf::serve::RebalanceConfig::default()
            },
        ))
    } else {
        None
    };
    let outcomes: Vec<(usize, acf::serve::LoadOutcome)> = if multi {
        // Tenant mix: every tenant offers an equal share; quota skew shows
        // up in what gets admitted. The rebalancer (if on) may shift
        // groups between models under this load.
        let tenant_corpora: Vec<Vec<Vec<i64>>> =
            tenant_mi.iter().map(|&mi| corpora[mi].clone()).collect();
        println!(
            "open loop ({} tenant(s), equal offered shares): {} requests at {:.0} img/s offered (Poisson arrivals, seed {})",
            server.n_tenants(),
            requests,
            offered,
            seed
        );
        acf::serve::open_loop_tenants(&server, &tenant_corpora, requests, offered, seed ^ 0x5E21)
    } else if rebalance {
        let low = (offered * 0.3).max(1.0);
        let spike = (offered * 1.6).max(1.0);
        let phases = [
            acf::serve::LoadPhase { requests: requests / 4, offered_img_s: low },
            acf::serve::LoadPhase { requests: requests / 2, offered_img_s: spike },
            acf::serve::LoadPhase {
                requests: requests - requests / 4 - requests / 2,
                offered_img_s: low,
            },
        ];
        println!(
            "step load: {} requests in phases {:.0} / {:.0} / {:.0} img/s offered (Poisson arrivals, seed {}; rebalance window {:?}, headroom {:.2})",
            requests,
            phases[0].offered_img_s,
            phases[1].offered_img_s,
            phases[2].offered_img_s,
            seed,
            window,
            headroom
        );
        acf::serve::step_load(&server, &corpora[0], &phases, seed ^ 0x5E21)
            .into_iter()
            .map(|o| (0, o))
            .collect()
    } else {
        println!(
            "open loop: {} requests at {:.0} img/s offered (Poisson arrivals, seed {})",
            requests, offered, seed
        );
        acf::serve::open_loop(&server, &corpora[0], requests, offered, seed ^ 0x5E21)
            .into_iter()
            .map(|o| (0, o))
            .collect()
    };
    if let Some(rb) = rb {
        rb.stop();
    }
    let mut load_mismatches = 0usize;
    let mut failures = 0usize;
    for (tn, o) in &outcomes {
        match &o.result {
            Ok(logits) => {
                if logits != &references[tenant_mi[*tn]][o.image_idx] {
                    load_mismatches += 1;
                }
            }
            Err(acf::serve::ServeError::Overloaded { .. }) => {} // counted by metrics
            Err(_) => failures += 1,
        }
    }
    let snap = server.shutdown();

    // 7. Report: per device group first (the heterogeneous view), then
    //    per replica.
    println!("\nmeasured fleet (host wall time; behavioral layer models):");
    print!("{}", acf::report::serve_group_table(&snap).plain());
    print!("{}", acf::report::serve_table(&snap).plain());
    if !snap.tenants.is_empty() {
        println!("\nper-tenant admission and latency (quota-weighted fair queueing):");
        print!("{}", acf::report::tenant_table(&snap).plain());
    }
    if rebalance {
        println!("\nrebalance timeline ({} action(s)):", snap.events.len());
        if !snap.events.is_empty() {
            print!("{}", acf::report::rebalance_table(&snap.events).plain());
        }
    }
    println!(
        "  requests: {} accepted, {} rejected (admission control), {} failed, queue peak {}",
        snap.accepted, snap.rejected, snap.failed, snap.queue_peak
    );
    println!(
        "  latency: p50 {:.2} ms  p95 {:.2} ms  p99 {:.2} ms  (mean {:.2} ms, admission to reply)",
        snap.p50_ms, snap.p95_ms, snap.p99_ms, snap.mean_ms
    );
    println!(
        "  throughput: {:.0} img/s sustained (measured, host) vs {:.0} img/s host replica-sum — {:.2}x",
        snap.sustained_img_s,
        replica_sum_host,
        snap.sustained_img_s / replica_sum_host.max(1e-9)
    );
    let modeled_mix = fp
        .groups
        .iter()
        .map(|g| {
            if fp.models.len() > 1 {
                format!(
                    "{} [{}] x{} @ {:.0}",
                    g.device.name,
                    fp.models[g.model_id].name,
                    g.replicas,
                    g.per_replica.images_per_sec
                )
            } else {
                format!(
                    "{} x{} @ {:.0}",
                    g.device.name, g.replicas, g.per_replica.images_per_sec
                )
            }
        })
        .collect::<Vec<_>>()
        .join(" + ");
    println!(
        "  modeled (FPGA @ {} MHz): {:.0} img/s fleet ({modeled_mix}; {:.3} W static) — the hardware this host simulation stands in for",
        clock, fp.fleet_img_s, fp.static_w
    );

    // 8. Trace export (--trace): attribute settle-scheduler activity to
    //    each group's planned conv engines on its control track (same
    //    clock as the request spans), then render everything the run
    //    recorded as one Chrome trace-event document.
    if let Some(path) = &trace_path {
        for (gi, g) in fp.groups.iter().enumerate() {
            for ep in &g.per_replica.engines {
                if ep.kind.conv_kind().is_none() {
                    continue;
                }
                let ctx = acf::trace::SettleTrace {
                    tracer: &tracer,
                    clock: &wall,
                    pid: acf::trace::pid_of_group(gi),
                    tid: acf::trace::TID_CONTROL,
                    label: format!("{} L{}", g.device.name, ep.layer),
                };
                match acf::sim::netlist_layer_check_traced(
                    &zoo[g.model_id],
                    &g.per_replica,
                    ep.layer,
                    seed,
                    8,
                    Some(&ctx),
                ) {
                    Ok(chk) => println!(
                        "  settle attribution: {} L{} — {} windows, {:.1}% of dense ops evaluated",
                        g.device.name,
                        ep.layer,
                        chk.windows,
                        chk.activity.evaluated_fraction() * 100.0
                    ),
                    Err(e) => return fail(format!("settle attribution ({}): {e}", g.device.name)),
                }
            }
        }
        let events = tracer.drain();
        let mut processes = vec![(acf::trace::PID_REQUESTS, "requests".to_string())];
        let mut threads = Vec::new();
        for (gi, label) in fp.group_labels().iter().enumerate() {
            processes.push((acf::trace::pid_of_group(gi), label.clone()));
            threads.push((acf::trace::pid_of_group(gi), acf::trace::TID_CONTROL, "control".to_string()));
        }
        // Every replica ever registered — retired ones keep their track.
        for (ri, r) in snap.replicas.iter().enumerate() {
            threads.push((
                acf::trace::pid_of_group(r.group),
                acf::trace::tid_of_replica(ri),
                format!("replica {ri}"),
            ));
        }
        let doc = acf::trace::chrome_trace(&events, &processes, &threads);
        if let Err(e) = std::fs::write(path, doc.dump()) {
            return fail(format!("{path}: {e}"));
        }
        println!(
            "\ntrace: {} events -> {path} ({} dropped by the ring buffer)",
            events.len(),
            tracer.dropped()
        );
        let stages = acf::trace::stage_summary(&events);
        if !stages.is_empty() {
            println!("trace critical path (per request stage, admission to reply):");
            print!("{}", acf::report::trace_summary(&stages).plain());
        }
    }
    if mismatches > 0 || load_mismatches > 0 || failures > 0 {
        eprintln!(
            "error: {mismatches} sample + {load_mismatches} load mismatches, {failures} failures"
        );
        return 1;
    }
    0
}

/// `--catalog` loading shared by the serve and scenario paths.
fn load_extra_catalog(a: &Args) -> Result<Vec<device::Device>, String> {
    match a.get_or("catalog", "none") {
        "none" | "auto" => Ok(Vec::new()),
        path => {
            let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
            device::load_catalog(&text).map_err(|e| format!("{path}: {e}"))
        }
    }
}

/// Parse a scenario document and plan the fleet it names. Shared by
/// `serve --scenario` and `scenario-check`; errors carry the offending
/// field but not the file name (callers prepend it).
fn plan_scenario(
    text: &str,
    extra: &[device::Device],
    clock: f64,
    policy: &acf::planner::Policy,
    max_replicas: usize,
) -> Result<(acf::serve::Scenario, acf::serve::FleetPlan), String> {
    let sc = acf::serve::Scenario::from_str(text)?;
    let spec = acf::serve::FleetSpec::parse(&sc.devices, extra)
        .map_err(|e| format!("devices: {e}"))?;
    // The model zoo the scenario's fleet must carry: the top-level model
    // for untenanted scenarios, otherwise every tenant's model in
    // first-use order (canonical names — they must match the group
    // model names the engine routes against).
    let mut names: Vec<&str> = Vec::new();
    if sc.tenants.is_empty() {
        names.push(&sc.model);
    } else {
        for t in &sc.tenants {
            if !names.contains(&t.model.as_str()) {
                names.push(&t.model);
            }
        }
    }
    let mut models = Vec::new();
    for n in &names {
        models.push(std::sync::Arc::new(
            model_by_name(n).map_err(|e| format!("model '{n}': {e}"))?,
        ));
    }
    let frontier =
        acf::serve::FleetFrontier::build_zoo(models, &spec, clock, policy, max_replicas)
            .map_err(|e| e.to_string())?;
    Ok((sc, acf::serve::compose_frontier(&frontier, None)))
}

/// `acf serve --scenario FILE`: run the deterministic fault-injection
/// engine against the modeled fleet the scenario names. Prints per-phase
/// verdicts and the fault timeline; exit code is the verdict (0 = PASS,
/// 1 = any failed assertion — including a clean whole-fleet loss).
fn cmd_serve_scenario(a: &Args, path: &str, clock: f64) -> i32 {
    let policy = match parse_policy(a) {
        Ok(p) => p,
        Err(e) => return fail(e),
    };
    let max_replicas = a.get_u64("max-replicas").unwrap().unwrap() as usize;
    let seed = a.get_u64("seed").unwrap().unwrap();
    let extra = match load_extra_catalog(a) {
        Ok(devs) => devs,
        Err(e) => return fail(e),
    };
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) => return fail(format!("{path}: {e}")),
    };
    let (sc, fp) = match plan_scenario(&text, &extra, clock, &policy, max_replicas) {
        Ok(v) => v,
        Err(e) => return fail(format!("{path}: {e}")),
    };
    let trace_path = match a.get_or("trace", "none") {
        "none" => None,
        p => Some(p.to_string()),
    };
    let tracer = if trace_path.is_some() {
        acf::trace::Tracer::ring(acf::trace::RingSink::DEFAULT_CAP)
    } else {
        acf::trace::Tracer::off()
    };
    println!(
        "scenario '{}' — {} (fleet {}, model {}, {} phase(s), seed {})",
        sc.name,
        sc.description,
        sc.devices,
        sc.model,
        sc.phases.len(),
        seed
    );
    if !sc.tenants.is_empty() {
        let roster = sc
            .tenants
            .iter()
            .map(|t| format!("{} -> {} (quota {})", t.name, t.model, t.quota))
            .collect::<Vec<_>>()
            .join(", ");
        println!("tenants: {roster}");
    }
    println!(
        "fleet plan @ {} MHz (policy {}): {} device group(s), {} replica(s), {:.1} img/s modeled",
        clock,
        policy.name,
        fp.groups.len(),
        fp.replicas(),
        fp.fleet_img_s
    );
    let opts = acf::serve::ScenarioOpts { seed, quick: false, tracer: tracer.clone() };
    let report = match acf::serve::run_scenario(&sc, &fp, &opts) {
        Ok(r) => r,
        Err(e) => return fail(format!("{path}: {e}")),
    };
    print!("{}", acf::report::scenario_table(&report).plain());
    if report.phases.iter().any(|p| !p.tenants.is_empty()) {
        println!("per-tenant phase breakdown:");
        print!("{}", acf::report::scenario_tenant_table(&report).plain());
    }
    if !report.faults.is_empty() {
        println!("fault timeline:");
        print!("{}", acf::report::fault_timeline_table(&report.faults).plain());
    }
    println!(
        "drops: {}  fleet_lost: {}  verdict: {}",
        report.drops,
        report.fleet_lost,
        if report.passed { "PASS" } else { "FAIL" }
    );
    match a.get_or("verdict", "none") {
        "none" => {}
        out => {
            if let Err(e) = std::fs::write(out, report.to_json().dump()) {
                return fail(format!("{out}: {e}"));
            }
            println!("verdict JSON -> {out}");
        }
    }
    if let Some(tpath) = &trace_path {
        let events = tracer.drain();
        let mut processes = vec![
            (acf::trace::PID_SCENARIO, "scenario".to_string()),
            (acf::trace::PID_REQUESTS, "requests".to_string()),
        ];
        let mut threads =
            vec![(acf::trace::PID_SCENARIO, acf::trace::TID_CONTROL, "phases".to_string())];
        let mut ri = 0usize;
        for (gi, g) in fp.groups.iter().enumerate() {
            processes.push((acf::trace::pid_of_group(gi), g.device.name.clone()));
            threads.push((
                acf::trace::pid_of_group(gi),
                acf::trace::TID_CONTROL,
                "control".to_string(),
            ));
            for _ in 0..g.replicas {
                threads.push((
                    acf::trace::pid_of_group(gi),
                    acf::trace::tid_of_replica(ri),
                    format!("replica {ri}"),
                ));
                ri += 1;
            }
        }
        let doc = acf::trace::chrome_trace(&events, &processes, &threads);
        if let Err(e) = std::fs::write(tpath, doc.dump()) {
            return fail(format!("{tpath}: {e}"));
        }
        println!(
            "trace: {} events -> {tpath} ({} dropped by the ring buffer)",
            events.len(),
            tracer.dropped()
        );
    }
    i32::from(!report.passed)
}

/// `acf scenario-check [DIR]`: run every `*.json` scenario in DIR
/// (default `scenarios`) against its planned fleet, write one
/// `SCENARIO_<name>.json` verdict per scenario, and exit non-zero if any
/// scenario fails. Quick mode (`ACF_BENCH_QUICK=1`) scales request
/// counts down for CI — profile shapes and verdict logic are unchanged.
fn cmd_scenario_check(argv: &[String]) -> i32 {
    let specs = vec![
        OptSpec { name: "out", value: true, help: "directory the SCENARIO_<name>.json verdict files are written to", default: Some(".") },
        OptSpec { name: "seed", value: true, help: "scenario seed (arrival jitter)", default: Some("7") },
        OptSpec { name: "clock-mhz", value: true, help: "FPGA clock for the fleet plans", default: Some("200") },
        OptSpec { name: "max-replicas", value: true, help: "per-device ceiling for the replica search", default: Some("8") },
        OptSpec { name: "policy", value: true, help: "adaptive|dsp-first|quantize-first|static-single", default: Some("adaptive") },
        OptSpec { name: "catalog", value: true, help: "JSON device-array file extending device lookups, or 'none'", default: Some("none") },
        opt_level_spec(),
        OptSpec { name: "help", value: false, help: "show help", default: None },
    ];
    let a = match Args::parse(argv, &specs) {
        Ok(a) => a,
        Err(e) => return fail(e),
    };
    if a.flag("help") {
        print!(
            "{}",
            help(
                "acf scenario-check [scenario-dir]",
                "run every scenario JSON in a directory and gate on the verdicts",
                &specs
            )
        );
        return 0;
    }
    if let Err(e) = apply_opt_level(&a) {
        return fail(e);
    }
    let dir = a.positional().first().map(String::as_str).unwrap_or("scenarios");
    let quick = acf::util::bench::quick_env();
    let seed = a.get_u64("seed").unwrap().unwrap();
    let clock = a.get_f64("clock-mhz").unwrap().unwrap();
    let max_replicas = a.get_u64("max-replicas").unwrap().unwrap() as usize;
    let policy = match parse_policy(&a) {
        Ok(p) => p,
        Err(e) => return fail(e),
    };
    let extra = match load_extra_catalog(&a) {
        Ok(devs) => devs,
        Err(e) => return fail(e),
    };
    let out_dir = a.get_or("out", ".");
    let mut files: Vec<std::path::PathBuf> = match std::fs::read_dir(dir) {
        Ok(rd) => rd
            .filter_map(|entry| entry.ok().map(|entry| entry.path()))
            .filter(|p| p.extension().map(|x| x == "json").unwrap_or(false))
            .collect(),
        Err(e) => return fail(format!("{dir}: {e}")),
    };
    files.sort();
    if files.is_empty() {
        return fail(format!("{dir}: no *.json scenarios found"));
    }
    let mut failures = 0usize;
    for path in &files {
        let text = match std::fs::read_to_string(path) {
            Ok(t) => t,
            Err(e) => return fail(format!("{}: {e}", path.display())),
        };
        let (sc, fp) = match plan_scenario(&text, &extra, clock, &policy, max_replicas) {
            Ok(v) => v,
            Err(e) => return fail(format!("{}: {e}", path.display())),
        };
        let opts = acf::serve::ScenarioOpts { seed, quick, tracer: acf::trace::Tracer::off() };
        let report = match acf::serve::run_scenario(&sc, &fp, &opts) {
            Ok(r) => r,
            Err(e) => return fail(format!("{}: {e}", path.display())),
        };
        let stem = path.file_stem().and_then(|s| s.to_str()).unwrap_or("scenario");
        let out_path = std::path::Path::new(out_dir).join(format!("SCENARIO_{stem}.json"));
        if let Err(e) = std::fs::write(&out_path, report.to_json().dump()) {
            return fail(format!("{}: {e}", out_path.display()));
        }
        println!(
            "{}: {} — {} phase(s), {} fault(s), {} drop(s) -> {}",
            path.display(),
            if report.passed { "PASS" } else { "FAIL" },
            report.phases.len(),
            report.faults.len(),
            report.drops,
            out_path.display()
        );
        if !report.passed {
            failures += 1;
        }
    }
    if failures > 0 {
        eprintln!("scenario-check: {failures} of {} scenario(s) failed", files.len());
        1
    } else {
        println!(
            "scenario-check: OK — {} scenario(s), seed {seed}, quick mode {}",
            files.len(),
            if quick { "on" } else { "off" }
        );
        0
    }
}

fn cmd_sweep(argv: &[String]) -> i32 {
    let mut specs = dev_specs();
    specs.push(OptSpec { name: "kind", value: true, help: "adaptation|precision", default: Some("adaptation") });
    let a = match Args::parse(argv, &specs) {
        Ok(a) => a,
        Err(e) => return fail(e),
    };
    if a.flag("help") {
        print!("{}", help("acf sweep", "device/precision sweeps", &specs));
        return 0;
    }
    if let Err(e) = apply_opt_level(&a) {
        return fail(e);
    }
    let clock = a.get_f64("clock-mhz").unwrap().unwrap();
    match a.get_or("kind", "adaptation") {
        "adaptation" => {
            println!("\nSWEEP-A — throughput (img/s) per device per policy, lenet-tiny\n{}", acf::report::sweep_adaptation(clock).markdown())
        }
        "precision" => {
            let dev = match get_device(&a) {
                Ok(d) => d,
                Err(e) => return fail(e),
            };
            println!("\nSWEEP-B — operand width vs IP (Conv_3's 8-bit ceiling)\n{}", acf::report::sweep_precision(&dev, clock).markdown())
        }
        other => return fail(format!("unknown sweep '{other}'")),
    }
    0
}

fn cmd_golden(argv: &[String]) -> i32 {
    let specs = vec![
        OptSpec { name: "images", value: true, help: "batch size", default: Some("16") },
        OptSpec { name: "seed", value: true, help: "data seed", default: Some("7") },
        OptSpec { name: "help", value: false, help: "show help", default: None },
    ];
    let a = match Args::parse(argv, &specs) {
        Ok(a) => a,
        Err(e) => return fail(e),
    };
    if a.flag("help") {
        print!("{}", help("acf golden", "run the AOT XLA artifact vs behavioral", &specs));
        return 0;
    }
    let Some(art) = acf::runtime::find_artifacts() else {
        return fail("artifacts/ not found — run `make artifacts`");
    };
    let client = match acf::runtime::cpu_client() {
        Ok(c) => c,
        Err(e) => return fail(e),
    };
    let golden = match acf::runtime::GoldenCnn::load(&client, &art) {
        Ok(g) => g,
        Err(e) => return fail(e),
    };
    let weights = acf::runtime::load_weights(&art).unwrap();
    let model = Model::lenet_tiny();
    let n = a.get_usize("images").unwrap().unwrap();
    let seed = a.get_u64("seed").unwrap().unwrap();
    let ds = Dataset::generate(n, seed, 16, 16);
    let mut ok = 0;
    for img in &ds.images {
        let g = golden.infer(&img.pix).unwrap();
        let b = acf::cnn::infer::infer(&model, &weights, &img.pix);
        if g == b {
            ok += 1;
        }
    }
    println!("golden XLA vs behavioral: {ok}/{n} bit-identical");
    i32::from(ok != n)
}

/// The bench files the CI gate covers.
const BENCH_FILES: [&str; 3] = ["BENCH_hotpath.json", "BENCH_serve.json", "BENCH_sim.json"];

fn cmd_bench_check(argv: &[String]) -> i32 {
    use acf::util::bench::{
        check_against_baseline, check_relations, parse_bench_doc, parse_relations, BenchCase,
        CheckReport,
    };
    use acf::util::json::Json;
    let specs = vec![
        OptSpec { name: "dir", value: true, help: "directory holding fresh BENCH_*.json", default: Some(".") },
        OptSpec { name: "baseline", value: true, help: "committed baseline directory", default: Some("BENCH_baseline") },
        OptSpec { name: "tolerance", value: true, help: "fractional slack for modeled series (0.05 = 5%)", default: Some("0.05") },
        OptSpec { name: "update", value: false, help: "rewrite the baseline (pinned) from the fresh files", default: None },
        OptSpec { name: "help", value: false, help: "show help", default: None },
    ];
    let a = match Args::parse(argv, &specs) {
        Ok(a) => a,
        Err(e) => return fail(e),
    };
    if a.flag("help") {
        print!("{}", help("acf bench-check", "gate fresh bench series against the committed baseline", &specs));
        return 0;
    }
    let dir = a.get_or("dir", ".");
    let baseline_dir = a.get_or("baseline", "BENCH_baseline");
    let tolerance = a.get_f64("tolerance").unwrap().unwrap();

    let load = |path: &std::path::Path| -> Result<Json, String> {
        let text =
            std::fs::read_to_string(path).map_err(|e| format!("{}: {e}", path.display()))?;
        Json::parse(&text).map_err(|e| format!("{}: {e}", path.display()))
    };

    // Fresh documents are mandatory — a missing file means the bench
    // never ran, which must not read as "no regression".
    let mut fresh = Vec::new();
    for file in BENCH_FILES {
        let path = std::path::Path::new(dir).join(file);
        let json = match load(&path) {
            Ok(j) => j,
            Err(e) => return fail(format!("fresh bench output missing: {e}")),
        };
        match parse_bench_doc(&json) {
            Ok(doc) => fresh.push((file, json, doc)),
            Err(e) => return fail(format!("{file}: {e}")),
        }
    }

    if a.flag("update") {
        if let Err(e) = std::fs::create_dir_all(baseline_dir) {
            return fail(format!("{baseline_dir}: {e}"));
        }
        for (file, json, _) in &fresh {
            let mut obj = match json.as_obj() {
                Ok(o) => o.clone(),
                Err(e) => return fail(format!("{file}: {e}")),
            };
            obj.insert("pinned".to_string(), Json::Bool(true));
            let path = std::path::Path::new(baseline_dir).join(file);
            if let Err(e) = std::fs::write(&path, Json::Obj(obj).dump()) {
                return fail(format!("{}: {e}", path.display()));
            }
            println!("pinned {}", path.display());
        }
        // Carry the relations file along so a refreshed directory is a
        // complete baseline (committing it must not drop the ordering
        // gates).
        let rel_dst = std::path::Path::new(baseline_dir).join("relations.json");
        if !rel_dst.exists() {
            let rel_src = std::path::Path::new("BENCH_baseline").join("relations.json");
            if rel_src.exists() {
                if let Err(e) = std::fs::copy(&rel_src, &rel_dst) {
                    return fail(format!("{}: {e}", rel_dst.display()));
                }
                println!("copied {} -> {}", rel_src.display(), rel_dst.display());
            }
        }
        println!("baseline refreshed — commit {baseline_dir}/ to activate the modeled gate");
        return 0;
    }

    let mut report = CheckReport::default();
    let all_cases: Vec<BenchCase> =
        fresh.iter().flat_map(|(_, _, d)| d.cases.iter().cloned()).collect();

    // Ordering relations (machine-independent — gate from day one).
    let rel_path = std::path::Path::new(baseline_dir).join("relations.json");
    match load(&rel_path) {
        Ok(json) => match parse_relations(&json) {
            Ok(rels) => report.merge(check_relations(&all_cases, &rels)),
            Err(e) => return fail(format!("{}: {e}", rel_path.display())),
        },
        Err(e) => return fail(format!("relations baseline missing: {e}")),
    }

    // Absolute modeled series vs the committed (pinned) baselines.
    for (file, _, doc) in &fresh {
        let path = std::path::Path::new(baseline_dir).join(file);
        let base = match load(&path).and_then(|j| parse_bench_doc(&j)) {
            Ok(b) => b,
            Err(e) => return fail(format!("baseline missing for {file}: {e}")),
        };
        report.merge(check_against_baseline(doc, &base, tolerance));
    }

    for note in &report.notes {
        println!("note: {note}");
    }
    if report.ok() {
        println!(
            "bench-check: OK — {} series across {} files, {} relation/baseline notes",
            all_cases.len(),
            BENCH_FILES.len(),
            report.notes.len()
        );
        0
    } else {
        for f in &report.failures {
            eprintln!("FAIL: {f}");
        }
        eprintln!("bench-check: {} failure(s)", report.failures.len());
        1
    }
}

fn cmd_trace_check(argv: &[String]) -> i32 {
    let specs = vec![OptSpec { name: "help", value: false, help: "show help", default: None }];
    let a = match Args::parse(argv, &specs) {
        Ok(a) => a,
        Err(e) => return fail(e),
    };
    if a.flag("help") || a.positional().is_empty() {
        print!(
            "{}",
            help(
                "acf trace-check <file.json>",
                "validate a Chrome trace-event JSON file (shape, required fields, span nesting)",
                &specs
            )
        );
        return i32::from(!a.flag("help"));
    }
    let mut code = 0;
    for path in a.positional() {
        let text = match std::fs::read_to_string(path) {
            Ok(t) => t,
            Err(e) => return fail(format!("{path}: {e}")),
        };
        let json = match acf::util::json::Json::parse(&text) {
            Ok(j) => j,
            Err(e) => return fail(format!("{path}: not valid JSON: {e}")),
        };
        match acf::trace::validate_chrome_trace(&json) {
            Ok(chk) => println!(
                "{path}: OK — {} events ({} spans, {} instants, {} metadata) on {} tracks ({} request chains)",
                chk.events, chk.spans, chk.instants, chk.metadata, chk.tracks, chk.request_tracks
            ),
            Err(e) => {
                eprintln!("{path}: INVALID — {e}");
                code = 1;
            }
        }
    }
    code
}

fn fail(e: impl std::fmt::Display) -> i32 {
    eprintln!("error: {e}");
    1
}
