//! End-to-end request tracing: span timelines from admission to settle.
//!
//! Every admitted request owns a chain of spans — `admit → queue_wait →
//! batch_form → dispatch → sim → reply` — stamped on one shared [`Clock`]
//! so the chain is contiguous and non-overlapping by construction (adjacent
//! stages share their boundary timestamp). Fleet-level events (rebalance
//! actions, replica add/retire/drain, shed decisions) and simulator
//! attribution spans (per-pass settle activity from
//! `netlist::sim::SettleStats`) land on the same clock, which makes the
//! export a single coherent timeline.
//!
//! Spans flow into a [`TraceSink`]. The production sink is a bounded
//! ring buffer ([`RingSink`]: one short mutex hold per event, drop-oldest
//! on overflow with a drop counter); when tracing is off the [`Tracer`]
//! holds no sink at all and every hot-path call site is a single
//! `Option::is_some` check. [`chrome_trace`] renders the drained events as
//! Chrome trace-event JSON (open in `chrome://tracing` or Perfetto) with
//! one track per replica and one per device group; [`validate_chrome_trace`]
//! is the CI checker for that format and [`stage_summary`] feeds the
//! `report::trace_summary` critical-path table.
//!
//! Track layout: requests live in process [`PID_REQUESTS`] with one thread
//! per request id; device group `g` is process `pid_of_group(g)` whose
//! thread 0 ([`TID_CONTROL`]) carries fleet events and settle attribution,
//! and whose thread block starting at `tid_of_replica(r)` carries replica
//! `r`'s micro-batches plus one thread per (concurrent) pipeline-layer
//! worker ([`layer_tid`]).

use std::collections::BTreeMap;
use std::collections::VecDeque;
use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::util::json::Json;

/// Process id of the per-request span chains (tid = request id).
pub const PID_REQUESTS: u64 = 1;
/// Process id of the scenario harness's own track: one span per phase
/// (tid 0), so fault instants on the group control tracks and shed
/// instants on the requests track line up against the phase that
/// produced them.
pub const PID_SCENARIO: u64 = 2;
/// Process ids of device groups start here (`pid_of_group`).
pub const GROUP_PID_BASE: u64 = 10;
/// Thread 0 of a group process: fleet events + settle attribution.
pub const TID_CONTROL: u64 = 0;

/// Trace process id for device group `g`.
pub fn pid_of_group(group: usize) -> u64 {
    GROUP_PID_BASE + group as u64
}

/// Thread ids reserved per replica inside its group's process: the
/// replica's own track (micro-batch spans) plus one track per pipeline
/// layer — the layer workers run *concurrently*, so their spans must not
/// share a track (partial overlap on one track is a malformed timeline).
pub const TIDS_PER_REPLICA: u64 = 32;

/// Trace thread id of replica `r`'s own track inside its group's
/// process. Offset past [`TID_CONTROL`]; each replica owns the block
/// `[tid_of_replica(r), tid_of_replica(r) + TIDS_PER_REPLICA)`.
pub fn tid_of_replica(replica: usize) -> u64 {
    1 + replica as u64 * TIDS_PER_REPLICA
}

/// Trace thread id for layer `layer`'s worker of the replica whose own
/// track is `base_tid` (= [`tid_of_replica`]). Models deeper than the
/// per-replica block wrap within it — layer tracks may then interleave,
/// but never bleed into another replica's block.
pub fn layer_tid(base_tid: u64, layer: usize) -> u64 {
    base_tid + 1 + layer as u64 % (TIDS_PER_REPLICA - 1)
}

/// The six per-request stages, in pipeline order.
pub const REQUEST_STAGES: [&str; 6] =
    ["admit", "queue_wait", "batch_form", "dispatch", "sim", "reply"];

// ---------------------------------------------------------------------------
// Clock
// ---------------------------------------------------------------------------

/// Injectable time source shared by metrics windows and trace spans.
///
/// `Clock::wall()` wraps a monotonic `Instant` taken at construction;
/// `Clock::manual()` is an atomic counter advanced explicitly by tests, so
/// windowed quantiles and span timestamps are deterministic without real
/// sleeps. Cloning a clock shares its zero point (and, for manual clocks,
/// the counter itself).
#[derive(Debug, Clone)]
pub struct Clock(ClockSrc);

#[derive(Debug, Clone)]
enum ClockSrc {
    Wall(Instant),
    Manual(Arc<AtomicU64>),
}

impl Clock {
    /// Monotonic wall clock with "now" as its zero point.
    pub fn wall() -> Clock {
        Clock(ClockSrc::Wall(Instant::now()))
    }

    /// Deterministic test clock starting at zero; advance with [`Clock::advance`].
    pub fn manual() -> Clock {
        Clock(ClockSrc::Manual(Arc::new(AtomicU64::new(0))))
    }

    /// Nanoseconds since the clock's zero point.
    pub fn now_nanos(&self) -> u64 {
        match &self.0 {
            ClockSrc::Wall(t0) => t0.elapsed().as_nanos() as u64,
            ClockSrc::Manual(n) => n.load(Ordering::Relaxed),
        }
    }

    /// Seconds since the clock's zero point.
    pub fn now_secs(&self) -> f64 {
        self.now_nanos() as f64 / 1e9
    }

    /// Move a manual clock forward. Panics on a wall clock — real time
    /// cannot be steered, and silently ignoring the call would make a
    /// mis-wired test pass vacuously.
    pub fn advance(&self, by: Duration) {
        match &self.0 {
            ClockSrc::Manual(n) => {
                n.fetch_add(by.as_nanos() as u64, Ordering::Relaxed);
            }
            ClockSrc::Wall(_) => panic!("Clock::advance is only valid on Clock::manual()"),
        }
    }
}

impl Default for Clock {
    fn default() -> Clock {
        Clock::wall()
    }
}

// ---------------------------------------------------------------------------
// Events
// ---------------------------------------------------------------------------

/// A typed argument value attached to a trace event.
#[derive(Debug, Clone, PartialEq)]
pub enum ArgValue {
    U(u64),
    F(f64),
    S(String),
}

impl ArgValue {
    fn to_json(&self) -> Json {
        match self {
            ArgValue::U(v) => Json::Num(*v as f64),
            ArgValue::F(v) => Json::Num(*v),
            ArgValue::S(v) => Json::Str(v.clone()),
        }
    }
}

/// Span (has a duration) or instant (a point marker).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    Span,
    Instant,
}

/// One recorded event. Timestamps are nanoseconds on the owning [`Clock`];
/// `(pid, tid)` select the track (see module docs for the layout).
#[derive(Debug, Clone)]
pub struct TraceEvent {
    pub name: String,
    /// Coarse category: `"request"`, `"replica"`, `"fleet"`, or `"sim"`.
    pub cat: &'static str,
    pub kind: EventKind,
    pub ts_nanos: u64,
    /// Zero for instants.
    pub dur_nanos: u64,
    pub pid: u64,
    pub tid: u64,
    pub args: Vec<(&'static str, ArgValue)>,
}

// ---------------------------------------------------------------------------
// Sinks
// ---------------------------------------------------------------------------

/// Destination for trace events. Implementations must tolerate concurrent
/// `record` calls from dispatcher, runner, and pipeline-worker threads.
pub trait TraceSink: Send + Sync + fmt::Debug {
    fn record(&self, ev: TraceEvent);
    /// Take all buffered events (oldest first), leaving the sink empty.
    fn drain(&self) -> Vec<TraceEvent>;
    /// Events discarded because the sink was full.
    fn dropped(&self) -> u64;
}

/// Bounded drop-oldest ring buffer. One short mutex hold per event; the
/// drop counter is lock-free so overflow is observable without draining.
#[derive(Debug)]
pub struct RingSink {
    cap: usize,
    buf: Mutex<VecDeque<TraceEvent>>,
    dropped: AtomicU64,
}

impl RingSink {
    /// Comfortable for quick serve runs: 6 spans/request plus per-layer and
    /// fleet events stays well under this for tens of thousands of requests.
    pub const DEFAULT_CAP: usize = 1 << 17;

    pub fn new(cap: usize) -> RingSink {
        RingSink {
            cap: cap.max(1),
            buf: Mutex::new(VecDeque::new()),
            dropped: AtomicU64::new(0),
        }
    }
}

impl TraceSink for RingSink {
    fn record(&self, ev: TraceEvent) {
        let mut buf = crate::util::sync::lock_ok(&self.buf);
        if buf.len() == self.cap {
            buf.pop_front();
            self.dropped.fetch_add(1, Ordering::Relaxed);
        }
        buf.push_back(ev);
    }

    fn drain(&self) -> Vec<TraceEvent> {
        std::mem::take(&mut *crate::util::sync::lock_ok(&self.buf)).into()
    }

    fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }
}

/// Discards everything. Exists so code paths that *require* a sink can be
/// exercised with tracing semantically off.
#[derive(Debug, Default)]
pub struct NoopSink;

impl TraceSink for NoopSink {
    fn record(&self, _ev: TraceEvent) {}
    fn drain(&self) -> Vec<TraceEvent> {
        Vec::new()
    }
    fn dropped(&self) -> u64 {
        0
    }
}

// ---------------------------------------------------------------------------
// Tracer
// ---------------------------------------------------------------------------

/// Cheap clonable handle given to every instrumented component.
///
/// `Tracer::off()` (the default) holds no sink: `on()` is false and every
/// instrumentation site skips argument construction entirely, so disabled
/// tracing costs one branch per site.
#[derive(Debug, Clone, Default)]
pub struct Tracer {
    sink: Option<Arc<dyn TraceSink>>,
}

impl Tracer {
    /// Tracing disabled; all record calls are no-ops.
    pub fn off() -> Tracer {
        Tracer { sink: None }
    }

    /// Trace into the given sink.
    pub fn new(sink: Arc<dyn TraceSink>) -> Tracer {
        Tracer { sink: Some(sink) }
    }

    /// Trace into a fresh bounded ring buffer of `cap` events.
    pub fn ring(cap: usize) -> Tracer {
        Tracer::new(Arc::new(RingSink::new(cap)))
    }

    /// True when a sink is attached. Call sites gate argument construction
    /// on this so disabled tracing stays off the hot path.
    pub fn on(&self) -> bool {
        self.sink.is_some()
    }

    pub fn record(&self, ev: TraceEvent) {
        if let Some(sink) = &self.sink {
            sink.record(ev);
        }
    }

    /// Record a completed span covering `[start_nanos, end_nanos]`.
    #[allow(clippy::too_many_arguments)]
    pub fn span(
        &self,
        name: impl Into<String>,
        cat: &'static str,
        pid: u64,
        tid: u64,
        start_nanos: u64,
        end_nanos: u64,
        args: Vec<(&'static str, ArgValue)>,
    ) {
        self.record(TraceEvent {
            name: name.into(),
            cat,
            kind: EventKind::Span,
            ts_nanos: start_nanos,
            dur_nanos: end_nanos.saturating_sub(start_nanos),
            pid,
            tid,
            args,
        });
    }

    /// Record a point event.
    pub fn instant(
        &self,
        name: impl Into<String>,
        cat: &'static str,
        pid: u64,
        tid: u64,
        ts_nanos: u64,
        args: Vec<(&'static str, ArgValue)>,
    ) {
        self.record(TraceEvent {
            name: name.into(),
            cat,
            kind: EventKind::Instant,
            ts_nanos,
            dur_nanos: 0,
            pid,
            tid,
            args,
        });
    }

    /// Drain the attached sink (empty when tracing is off).
    pub fn drain(&self) -> Vec<TraceEvent> {
        match &self.sink {
            Some(sink) => sink.drain(),
            None => Vec::new(),
        }
    }

    /// Drop count of the attached sink (zero when tracing is off).
    pub fn dropped(&self) -> u64 {
        self.sink.as_ref().map_or(0, |s| s.dropped())
    }
}

// ---------------------------------------------------------------------------
// Settle attribution context
// ---------------------------------------------------------------------------

/// Context handed into the netlist-simulation paths so per-pass settle
/// spans land on a fleet track with `SettleStats` deltas attached.
pub struct SettleTrace<'a> {
    pub tracer: &'a Tracer,
    pub clock: &'a Clock,
    pub pid: u64,
    pub tid: u64,
    /// Prefix for span names, e.g. `"zcu104 L0"`.
    pub label: String,
}

// ---------------------------------------------------------------------------
// Chrome trace-event export
// ---------------------------------------------------------------------------

fn micros(nanos: u64) -> Json {
    Json::Num(nanos as f64 / 1000.0)
}

fn meta_event(name: &str, pid: u64, tid: Option<u64>, label: &str) -> Json {
    let mut o = BTreeMap::new();
    o.insert("name".to_string(), Json::Str(name.to_string()));
    o.insert("ph".to_string(), Json::Str("M".to_string()));
    o.insert("ts".to_string(), Json::Num(0.0));
    o.insert("pid".to_string(), Json::Num(pid as f64));
    if let Some(tid) = tid {
        o.insert("tid".to_string(), Json::Num(tid as f64));
    }
    let mut args = BTreeMap::new();
    args.insert("name".to_string(), Json::Str(label.to_string()));
    o.insert("args".to_string(), Json::Obj(args));
    Json::Obj(o)
}

/// Render events as a Chrome trace-event document (`chrome://tracing`,
/// Perfetto). `processes` names process tracks as `(pid, label)`;
/// `threads` names thread tracks as `(pid, tid, label)` — pass every
/// replica ever registered, retired ones included, so their history keeps
/// a labelled track. Spans become `ph:"X"` complete events, instants
/// `ph:"i"`, labels `ph:"M"` metadata; timestamps are microseconds.
pub fn chrome_trace(
    events: &[TraceEvent],
    processes: &[(u64, String)],
    threads: &[(u64, u64, String)],
) -> Json {
    let mut out = Vec::with_capacity(events.len() + processes.len() + threads.len());
    for (pid, label) in processes {
        out.push(meta_event("process_name", *pid, None, label));
    }
    for (pid, tid, label) in threads {
        out.push(meta_event("thread_name", *pid, Some(*tid), label));
    }
    for ev in events {
        let mut o = BTreeMap::new();
        o.insert("name".to_string(), Json::Str(ev.name.clone()));
        o.insert("cat".to_string(), Json::Str(ev.cat.to_string()));
        o.insert("ts".to_string(), micros(ev.ts_nanos));
        o.insert("pid".to_string(), Json::Num(ev.pid as f64));
        o.insert("tid".to_string(), Json::Num(ev.tid as f64));
        match ev.kind {
            EventKind::Span => {
                o.insert("ph".to_string(), Json::Str("X".to_string()));
                o.insert("dur".to_string(), micros(ev.dur_nanos));
            }
            EventKind::Instant => {
                o.insert("ph".to_string(), Json::Str("i".to_string()));
                o.insert("s".to_string(), Json::Str("t".to_string()));
            }
        }
        if !ev.args.is_empty() {
            let args: BTreeMap<String, Json> =
                ev.args.iter().map(|(k, v)| (k.to_string(), v.to_json())).collect();
            o.insert("args".to_string(), Json::Obj(args));
        }
        out.push(Json::Obj(o));
    }
    let mut doc = BTreeMap::new();
    doc.insert("displayTimeUnit".to_string(), Json::Str("ms".to_string()));
    doc.insert("traceEvents".to_string(), Json::Arr(out));
    Json::Obj(doc)
}

// ---------------------------------------------------------------------------
// Chrome trace validation (CI checker)
// ---------------------------------------------------------------------------

/// What [`validate_chrome_trace`] counted in a well-formed document.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct TraceCheck {
    pub events: usize,
    pub spans: usize,
    pub instants: usize,
    pub metadata: usize,
    /// Distinct `(pid, tid)` pairs carrying spans or instants.
    pub tracks: usize,
    /// Tracks in [`PID_REQUESTS`], i.e. per-request span chains.
    pub request_tracks: usize,
}

fn field_u64(ev: &Json, key: &str, idx: usize) -> Result<u64, String> {
    let v = ev
        .get(key)
        .map_err(|_| format!("event {idx}: missing required field '{key}'"))?;
    let f = v
        .as_f64()
        .map_err(|_| format!("event {idx}: field '{key}' is not a number"))?;
    if f < 0.0 {
        return Err(format!("event {idx}: field '{key}' is negative"));
    }
    Ok(f as u64)
}

/// Validate a Chrome trace-event document: top-level shape, required
/// `name`/`ph`/`ts`/`pid`/`tid` fields (`dur` on complete spans), and —
/// per track — that spans either nest or are disjoint (partial overlap is
/// a malformed timeline). Used by `acf trace-check` in CI.
pub fn validate_chrome_trace(doc: &Json) -> Result<TraceCheck, String> {
    let events = match doc {
        Json::Obj(_) => doc
            .get("traceEvents")
            .map_err(|_| "top-level object lacks 'traceEvents'".to_string())?
            .as_arr()
            .map_err(|_| "'traceEvents' is not an array".to_string())?,
        Json::Arr(a) => a.as_slice(),
        _ => return Err("trace document must be an object or array".to_string()),
    };
    let mut check = TraceCheck { events: events.len(), ..TraceCheck::default() };
    // (pid, tid) -> [(ts_nanos_scaled, end)] in ts units (µs as f64).
    let mut spans_by_track: BTreeMap<(u64, u64), Vec<(f64, f64)>> = BTreeMap::new();
    let mut tracks: std::collections::BTreeSet<(u64, u64)> = std::collections::BTreeSet::new();
    for (idx, ev) in events.iter().enumerate() {
        if ev.as_obj().is_err() {
            return Err(format!("event {idx}: not an object"));
        }
        let ph = ev
            .get("ph")
            .and_then(|v| v.as_str().map(str::to_string))
            .map_err(|_| format!("event {idx}: missing required field 'ph'"))?;
        ev.get("name")
            .and_then(|v| v.as_str().map(drop))
            .map_err(|_| format!("event {idx}: missing required field 'name'"))?;
        let pid = field_u64(ev, "pid", idx)?;
        let ts = ev
            .get("ts")
            .and_then(|v| v.as_f64())
            .map_err(|_| format!("event {idx}: missing required field 'ts'"))?;
        match ph.as_str() {
            "M" => check.metadata += 1,
            "X" => {
                let tid = field_u64(ev, "tid", idx)?;
                let dur = ev
                    .get("dur")
                    .and_then(|v| v.as_f64())
                    .map_err(|_| format!("event {idx}: complete span lacks 'dur'"))?;
                if dur < 0.0 {
                    return Err(format!("event {idx}: negative span duration"));
                }
                check.spans += 1;
                tracks.insert((pid, tid));
                spans_by_track.entry((pid, tid)).or_default().push((ts, ts + dur));
            }
            "i" | "I" => {
                let tid = field_u64(ev, "tid", idx)?;
                check.instants += 1;
                tracks.insert((pid, tid));
            }
            other => return Err(format!("event {idx}: unsupported phase '{other}'")),
        }
    }
    for ((pid, tid), spans) in spans_by_track.iter_mut() {
        spans.sort_by(|a, b| a.partial_cmp(b).unwrap());
        // Stack-check nesting: each span must close before any enclosing one.
        let mut stack: Vec<(f64, f64)> = Vec::new();
        for &(start, end) in spans.iter() {
            while let Some(&(_, open_end)) = stack.last() {
                if open_end <= start {
                    stack.pop();
                } else {
                    break;
                }
            }
            if let Some(&(_, open_end)) = stack.last() {
                if end > open_end {
                    return Err(format!(
                        "track pid={pid} tid={tid}: span [{start}, {end}] partially \
                         overlaps enclosing span ending at {open_end}"
                    ));
                }
            }
            stack.push((start, end));
        }
    }
    check.tracks = tracks.len();
    check.request_tracks = tracks.iter().filter(|(pid, _)| *pid == PID_REQUESTS).count();
    Ok(check)
}

// ---------------------------------------------------------------------------
// Per-stage summary
// ---------------------------------------------------------------------------

/// Aggregate latency of one request stage across the trace.
#[derive(Debug, Clone, PartialEq)]
pub struct StageStat {
    pub stage: &'static str,
    pub count: u64,
    pub mean_ms: f64,
    pub p99_ms: f64,
}

/// Mean/p99 per request stage, in pipeline order, from drained events.
/// Only `cat == "request"` spans contribute; stages never observed are
/// omitted. Feeds `report::trace_summary`.
pub fn stage_summary(events: &[TraceEvent]) -> Vec<StageStat> {
    let mut out = Vec::new();
    for stage in REQUEST_STAGES {
        let mut durs: Vec<u64> = events
            .iter()
            .filter(|e| e.cat == "request" && e.kind == EventKind::Span && e.name == stage)
            .map(|e| e.dur_nanos)
            .collect();
        if durs.is_empty() {
            continue;
        }
        durs.sort_unstable();
        let total: u128 = durs.iter().map(|&d| d as u128).sum();
        let mean_ms = total as f64 / durs.len() as f64 / 1e6;
        // Nearest-rank p99, matching serve::metrics quantiles.
        let rank = ((durs.len() as f64) * 0.99).ceil() as usize;
        let p99_ms = durs[rank.clamp(1, durs.len()) - 1] as f64 / 1e6;
        out.push(StageStat { stage, count: durs.len() as u64, mean_ms, p99_ms });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(name: &str, pid: u64, tid: u64, start: u64, end: u64) -> TraceEvent {
        TraceEvent {
            name: name.to_string(),
            cat: "request",
            kind: EventKind::Span,
            ts_nanos: start,
            dur_nanos: end - start,
            pid,
            tid,
            args: Vec::new(),
        }
    }

    #[test]
    fn manual_clock_is_deterministic_and_shared_across_clones() {
        let c = Clock::manual();
        let c2 = c.clone();
        assert_eq!(c.now_nanos(), 0);
        c.advance(Duration::from_millis(3));
        assert_eq!(c.now_nanos(), 3_000_000);
        assert_eq!(c2.now_nanos(), 3_000_000, "clones share the counter");
        c2.advance(Duration::from_nanos(5));
        assert_eq!(c.now_nanos(), 3_000_005);
    }

    #[test]
    fn replica_tid_blocks_never_collide() {
        // Replica tracks stay clear of TID_CONTROL, and one replica's
        // layer tracks (any depth) never reach the next replica's block.
        for r in 0..8 {
            assert!(tid_of_replica(r) > TID_CONTROL);
            for layer in 0..100 {
                let t = layer_tid(tid_of_replica(r), layer);
                assert!(t > tid_of_replica(r));
                assert!(t < tid_of_replica(r + 1));
            }
        }
    }

    #[test]
    fn wall_clock_is_monotone() {
        let c = Clock::wall();
        let a = c.now_nanos();
        let b = c.now_nanos();
        assert!(b >= a);
    }

    #[test]
    #[should_panic(expected = "only valid on Clock::manual")]
    fn advancing_a_wall_clock_panics() {
        Clock::wall().advance(Duration::from_secs(1));
    }

    #[test]
    fn ring_sink_bounds_memory_and_counts_drops() {
        let sink = RingSink::new(3);
        for i in 0..5u64 {
            sink.record(span("s", 1, i, i, i + 1));
        }
        assert_eq!(sink.dropped(), 2);
        let kept = sink.drain();
        assert_eq!(kept.len(), 3);
        // Drop-oldest: the survivors are the three most recent events.
        assert_eq!(kept.iter().map(|e| e.tid).collect::<Vec<_>>(), vec![2, 3, 4]);
        assert!(sink.drain().is_empty(), "drain empties the buffer");
    }

    #[test]
    fn disabled_tracer_records_nothing() {
        let t = Tracer::off();
        assert!(!t.on());
        t.span("x", "request", 1, 1, 0, 10, Vec::new());
        t.instant("y", "fleet", 1, 1, 5, Vec::new());
        assert!(t.drain().is_empty());
        assert_eq!(t.dropped(), 0);
    }

    #[test]
    fn tracer_span_and_instant_round_trip() {
        let t = Tracer::ring(16);
        assert!(t.on());
        t.span("admit", "request", PID_REQUESTS, 7, 100, 250, vec![("n", ArgValue::U(3))]);
        t.instant("shed", "fleet", PID_REQUESTS, 8, 300, Vec::new());
        let evs = t.drain();
        assert_eq!(evs.len(), 2);
        assert_eq!(evs[0].name, "admit");
        assert_eq!(evs[0].kind, EventKind::Span);
        assert_eq!((evs[0].ts_nanos, evs[0].dur_nanos), (100, 150));
        assert_eq!(evs[1].kind, EventKind::Instant);
    }

    #[test]
    fn chrome_export_round_trips_through_validator() {
        let events = vec![
            span("admit", PID_REQUESTS, 1, 0, 1000),
            span("queue_wait", PID_REQUESTS, 1, 1000, 4000),
            TraceEvent {
                name: "rebalance_grow".to_string(),
                cat: "fleet",
                kind: EventKind::Instant,
                ts_nanos: 2500,
                dur_nanos: 0,
                pid: pid_of_group(0),
                tid: TID_CONTROL,
                args: vec![("from", ArgValue::U(1)), ("to", ArgValue::U(2))],
            },
        ];
        let doc = chrome_trace(
            &events,
            &[(PID_REQUESTS, "requests".to_string()), (pid_of_group(0), "zcu104".to_string())],
            &[(pid_of_group(0), tid_of_replica(0), "replica 0".to_string())],
        );
        // Survives its own serialization.
        let parsed = Json::parse(&doc.dump()).expect("export is valid JSON");
        let check = validate_chrome_trace(&parsed).expect("export is a valid chrome trace");
        assert_eq!(check.spans, 2);
        assert_eq!(check.instants, 1);
        assert_eq!(check.metadata, 3);
        assert_eq!(check.request_tracks, 1);
    }

    #[test]
    fn validator_accepts_nested_spans_but_rejects_partial_overlap() {
        // batch span [0, 100] containing layer spans [10, 40] and [40, 90]: ok.
        let nested = chrome_trace(
            &[
                span("infer_batch", 10, 1, 0, 100),
                span("layer0", 10, 1, 10, 40),
                span("layer1", 10, 1, 40, 90),
            ],
            &[],
            &[],
        );
        validate_chrome_trace(&nested).expect("nesting is legal");

        let overlapping =
            chrome_trace(&[span("a", 10, 1, 0, 100_000), span("b", 10, 1, 50_000, 150_000)], &[], &[]);
        let err = validate_chrome_trace(&overlapping).unwrap_err();
        assert!(err.contains("partially"), "got: {err}");
    }

    #[test]
    fn validator_rejects_missing_required_fields() {
        let doc = Json::parse(r#"{"traceEvents":[{"name":"x","ph":"X","ts":0,"pid":1}]}"#).unwrap();
        let err = validate_chrome_trace(&doc).unwrap_err();
        assert!(err.contains("tid"), "got: {err}");

        let doc = Json::parse(r#"{"traceEvents":[{"ph":"X","ts":0,"pid":1,"tid":1,"dur":1}]}"#).unwrap();
        let err = validate_chrome_trace(&doc).unwrap_err();
        assert!(err.contains("name"), "got: {err}");

        let doc =
            Json::parse(r#"{"traceEvents":[{"name":"x","ph":"X","ts":0,"pid":1,"tid":1}]}"#).unwrap();
        let err = validate_chrome_trace(&doc).unwrap_err();
        assert!(err.contains("dur"), "got: {err}");
    }

    #[test]
    fn stage_summary_means_and_p99_are_exact_on_known_durations() {
        let mut events = Vec::new();
        // 100 admit spans of 1ms..100ms.
        for i in 1..=100u64 {
            events.push(span("admit", PID_REQUESTS, i, 0, i * 1_000_000));
        }
        events.push(span("reply", PID_REQUESTS, 1, 0, 2_000_000));
        // A replica-track span must not contaminate request stages.
        let mut batch = span("admit", 10, 1, 0, 500_000_000);
        batch.cat = "replica";
        events.push(batch);

        let stats = stage_summary(&events);
        assert_eq!(stats.len(), 2);
        assert_eq!(stats[0].stage, "admit");
        assert_eq!(stats[0].count, 100);
        assert!((stats[0].mean_ms - 50.5).abs() < 1e-9);
        assert!((stats[0].p99_ms - 99.0).abs() < 1e-9);
        assert_eq!(stats[1].stage, "reply");
        assert!((stats[1].p99_ms - 2.0).abs() < 1e-9);
    }
}
