//! Deployment metrics: thread-safe counters the leader reports.
//!
//! Besides batch totals, the pipeline records *per-layer* worker wall
//! time, keyed by the same layer indices the engine plan uses — so a
//! report can put modeled cycles (from [`crate::planner::EnginePlan`])
//! and measured host time side by side for every layer.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Cumulative serving metrics.
#[derive(Debug, Default)]
pub struct Metrics {
    images: AtomicU64,
    batches: AtomicU64,
    wall_nanos: AtomicU64,
    /// Per-layer worker wall time (nanoseconds), index = layer index.
    layer_nanos: Vec<AtomicU64>,
}

/// A point-in-time view.
#[derive(Debug, Clone)]
pub struct Snapshot {
    pub images: u64,
    pub batches: u64,
    pub wall_secs: f64,
    /// Cumulative per-layer worker seconds (empty when the deployment was
    /// built without layer accounting).
    pub layer_secs: Vec<f64>,
}

impl Snapshot {
    pub fn throughput(&self) -> f64 {
        if self.wall_secs > 0.0 {
            self.images as f64 / self.wall_secs
        } else {
            0.0
        }
    }

    /// The layer whose workers burned the most wall time (the measured
    /// counterpart of the plan's modeled bottleneck). `None` until some
    /// layer has actually recorded work.
    pub fn hottest_layer(&self) -> Option<usize> {
        self.layer_secs
            .iter()
            .enumerate()
            .filter(|(_, &s)| s > 0.0)
            .max_by(|a, b| a.1.total_cmp(b.1))
            .map(|(i, _)| i)
    }
}

impl Metrics {
    /// Metrics with per-layer accounting for `n_layers` pipeline stages.
    pub fn with_layers(n_layers: usize) -> Metrics {
        Metrics {
            layer_nanos: (0..n_layers).map(|_| AtomicU64::new(0)).collect(),
            ..Metrics::default()
        }
    }

    pub fn record_batch(&self, images: u64, wall: Duration) {
        self.images.fetch_add(images, Ordering::Relaxed);
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.wall_nanos.fetch_add(wall.as_nanos() as u64, Ordering::Relaxed);
    }

    /// Record one worker invocation for layer `li` (no-op for layers the
    /// metrics were not sized for).
    pub fn record_layer(&self, li: usize, wall: Duration) {
        if let Some(cell) = self.layer_nanos.get(li) {
            cell.fetch_add(wall.as_nanos() as u64, Ordering::Relaxed);
        }
    }

    pub fn snapshot(&self) -> Snapshot {
        Snapshot {
            images: self.images.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            wall_secs: self.wall_nanos.load(Ordering::Relaxed) as f64 / 1e9,
            layer_secs: self
                .layer_nanos
                .iter()
                .map(|n| n.load(Ordering::Relaxed) as f64 / 1e9)
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates() {
        let m = Metrics::default();
        m.record_batch(3, Duration::from_millis(10));
        m.record_batch(5, Duration::from_millis(30));
        let s = m.snapshot();
        assert_eq!(s.images, 8);
        assert_eq!(s.batches, 2);
        assert!((s.wall_secs - 0.04).abs() < 1e-6);
        assert!(s.throughput() > 0.0);
        assert!(s.layer_secs.is_empty());
        assert_eq!(s.hottest_layer(), None);
    }

    #[test]
    fn per_layer_accounting() {
        let m = Metrics::with_layers(3);
        m.record_layer(0, Duration::from_millis(1));
        m.record_layer(2, Duration::from_millis(5));
        m.record_layer(2, Duration::from_millis(5));
        m.record_layer(9, Duration::from_millis(99)); // out of range: ignored
        let s = m.snapshot();
        assert_eq!(s.layer_secs.len(), 3);
        assert!((s.layer_secs[0] - 0.001).abs() < 1e-9);
        assert_eq!(s.layer_secs[1], 0.0);
        assert!((s.layer_secs[2] - 0.010).abs() < 1e-9);
        assert_eq!(s.hottest_layer(), Some(2));
    }
}
