//! Deployment metrics: thread-safe counters the leader reports.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Cumulative serving metrics.
#[derive(Debug, Default)]
pub struct Metrics {
    images: AtomicU64,
    batches: AtomicU64,
    wall_nanos: AtomicU64,
}

/// A point-in-time view.
#[derive(Debug, Clone, Copy)]
pub struct Snapshot {
    pub images: u64,
    pub batches: u64,
    pub wall_secs: f64,
}

impl Snapshot {
    pub fn throughput(&self) -> f64 {
        if self.wall_secs > 0.0 {
            self.images as f64 / self.wall_secs
        } else {
            0.0
        }
    }
}

impl Metrics {
    pub fn record_batch(&self, images: u64, wall: Duration) {
        self.images.fetch_add(images, Ordering::Relaxed);
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.wall_nanos.fetch_add(wall.as_nanos() as u64, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> Snapshot {
        Snapshot {
            images: self.images.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            wall_secs: self.wall_nanos.load(Ordering::Relaxed) as f64 / 1e9,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates() {
        let m = Metrics::default();
        m.record_batch(3, Duration::from_millis(10));
        m.record_batch(5, Duration::from_millis(30));
        let s = m.snapshot();
        assert_eq!(s.images, 8);
        assert_eq!(s.batches, 2);
        assert!((s.wall_secs - 0.04).abs() < 1e-6);
        assert!(s.throughput() > 0.0);
    }
}
