//! Deployment coordinator — the L3 run-time that owns process topology,
//! worker threads, backpressure, and metrics.
//!
//! A [`Deployment`] realizes a [`Plan`]: one *persistent* worker thread
//! per layer, connected by bounded channels (the fabric's line-buffer
//! backpressure, modeled at lane-group granularity). The workers are
//! spawned once at deployment time and live until the `Deployment` is
//! dropped — both the one-shot [`Deployment::infer_batch`] path and the
//! serving tier ([`crate::serve`]) feed the same pipeline, and any number
//! of callers may submit concurrently: every in-flight job carries its
//! own reply channel, so interleaved batches never cross-talk and each
//! caller still gets its outputs in submission order.
//!
//! Jobs are *lane groups*, not single images: a micro-batch is packed
//! into groups of up to [`crate::netlist::sim::LANES`] images that travel
//! the pipeline together — the execution-side counterpart of the
//! simulator's 64-lane settle/tick passes (the ROADMAP's "batch-aware
//! engine plans" item, execution half). Values are computed with the
//! bit-exact behavioral layer models (the netlists are spot-verified
//! against them by [`crate::sim::netlist_layer_check`], itself
//! lane-batched); time comes from the engine plan's schedule model, and
//! per-layer worker wall time is recorded in [`metrics::Metrics`] keyed
//! by the same layer indices the engine plan uses. Python never appears
//! here — the XLA golden path lives in [`crate::runtime`] and is only
//! consulted for verification.

pub mod metrics;

use crate::cnn::infer::Tensor;
use crate::cnn::model::{Layer, Model, Weights};
use crate::fabric::device::Device;
use crate::netlist::sim::LANES;
use crate::planner::{plan as make_plan, Plan, PlanError, Policy};
use crate::trace::{ArgValue, Clock, Tracer};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};

/// Channel depth between layer workers (double-buffered line memories).
const CHANNEL_DEPTH: usize = 2;

/// One in-flight lane group: up to [`LANES`] activation tensors pushed
/// through the layer stages together, each with its caller's batch
/// position, plus the caller's reply channel. Carrying the reply with the
/// work is what lets multiple batches interleave on one pipeline without
/// a demultiplexer.
struct Job {
    tensors: Vec<Tensor>,
    tags: Vec<usize>,
    reply: mpsc::Sender<(usize, Vec<i64>)>,
}

/// Lane-group width for a `batch`-image submission on an `n_layers`-deep
/// pipeline: as wide as possible (fewer channel handoffs, one job per
/// micro-batch when it fits a lane word) while still splitting large
/// batches into at least one group per layer worker so the pipeline
/// stays full, and never wider than the simulator's lane count.
fn lane_group_width(batch: usize, n_layers: usize) -> usize {
    batch.div_ceil(n_layers.max(1)).clamp(1, LANES)
}

/// Where a replica pipeline's per-layer spans go. A deployment is built
/// *before* the serving tier knows its replica id, so the trace context
/// is attached after registration ([`Deployment::attach_trace`]) and can
/// be re-attached when a deployment moves to a later server. The `on`
/// flag keeps the per-job cost of disabled tracing to one relaxed load
/// per layer; the context itself lives behind a mutex that is only
/// locked when tracing is live.
#[derive(Debug, Default)]
struct PipelineTrace {
    on: AtomicBool,
    ctx: Mutex<Option<TraceCtx>>,
}

#[derive(Debug, Clone)]
struct TraceCtx {
    tracer: Tracer,
    clock: Clock,
    pid: u64,
    tid: u64,
}

/// The persistent layer pipeline: one long-lived thread per layer plus an
/// egress thread, all fed by bounded `sync_channel`s. Built once per
/// deployment; torn down (sender dropped, workers joined) on drop.
struct Pipeline {
    /// `None` only during teardown. Callers clone the sender out from
    /// under the mutex and submit without holding the lock.
    ingress: Mutex<Option<mpsc::SyncSender<Job>>>,
    workers: Vec<std::thread::JoinHandle<()>>,
    /// Lane-group jobs submitted but not yet fully replied — the drain
    /// signal the serving tier polls before retiring a replica pipeline
    /// (covers one-shot `infer_batch` callers the scheduler cannot see).
    in_flight: Arc<AtomicU64>,
    trace: Arc<PipelineTrace>,
}

impl Pipeline {
    fn start(model: Arc<Model>, weights: Arc<Weights>, metrics: Arc<metrics::Metrics>) -> Pipeline {
        let n_layers = model.layers.len();
        let (tx0, mut rx_prev) = mpsc::sync_channel::<Job>(CHANNEL_DEPTH);
        let mut workers = Vec::with_capacity(n_layers + 1);
        let trace = Arc::new(PipelineTrace::default());
        for li in 0..n_layers {
            let (tx, rx_next) = mpsc::sync_channel::<Job>(CHANNEL_DEPTH);
            let rx_in = rx_prev;
            rx_prev = rx_next;
            let model = Arc::clone(&model);
            let weights = Arc::clone(&weights);
            let metrics = Arc::clone(&metrics);
            let trace = Arc::clone(&trace);
            workers.push(std::thread::spawn(move || {
                // Geometry is a per-layer constant — computed once per
                // worker lifetime, not per image (DESIGN.md §Perf item 5).
                let geom = layer_input_geometry(&model, li);
                while let Ok(mut job) = rx_in.recv() {
                    // One relaxed load per job when tracing is off; the
                    // context mutex is only touched when it is on.
                    let span_ctx = if trace.on.load(Ordering::Relaxed) {
                        trace
                            .ctx
                            .lock()
                            .unwrap()
                            .clone()
                            .map(|c| (c.clock.now_nanos(), c))
                    } else {
                        None
                    };
                    let lt0 = std::time::Instant::now();
                    for tensor in job.tensors.iter_mut() {
                        *tensor = apply_layer(&model, &weights, li, tensor, geom);
                    }
                    metrics.record_layer(li, lt0.elapsed());
                    if let Some((t0, c)) = span_ctx {
                        // Layer workers run concurrently, so each layer
                        // gets its own thread track in the replica's
                        // tid block.
                        c.tracer.span(
                            format!("layer{li}"),
                            "sim",
                            c.pid,
                            crate::trace::layer_tid(c.tid, li),
                            t0,
                            c.clock.now_nanos(),
                            vec![("images", ArgValue::U(job.tensors.len() as u64))],
                        );
                    }
                    if tx.send(job).is_err() {
                        return; // downstream gone
                    }
                }
            }));
        }
        // Egress: flatten and route each result back to its caller. Reply
        // channels are unbounded, so egress never blocks and the pipeline
        // cannot deadlock however many batches are in flight.
        let in_flight = Arc::new(AtomicU64::new(0));
        let egress_in_flight = Arc::clone(&in_flight);
        workers.push(std::thread::spawn(move || {
            while let Ok(job) = rx_prev.recv() {
                let Job { tensors, tags, reply } = job;
                for (tag, tensor) in tags.into_iter().zip(tensors) {
                    let _ = reply.send((tag, tensor.concat()));
                }
                egress_in_flight.fetch_sub(1, Ordering::Release);
            }
        }));
        Pipeline { ingress: Mutex::new(Some(tx0)), workers, in_flight, trace }
    }

    /// A cloned handle to the ingress channel, or `None` mid-teardown.
    fn sender(&self) -> Option<mpsc::SyncSender<Job>> {
        self.ingress.lock().unwrap().clone()
    }
}

impl Drop for Pipeline {
    fn drop(&mut self) {
        // Dropping the ingress sender lets the recv-loop cascade wind the
        // workers down; join so no thread outlives the deployment.
        *self.ingress.lock().unwrap() = None;
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

/// A deployed model ready to serve batches.
pub struct Deployment {
    pub model: Arc<Model>,
    pub weights: Arc<Weights>,
    pub plan: Plan,
    pub metrics: Arc<metrics::Metrics>,
    pipeline: Pipeline,
}

#[derive(Debug)]
pub enum DeployError {
    Plan(PlanError),
    BadImage { got: usize, want: usize },
    AsymmetricInput(i64),
    /// A layer worker exited (panicked) before the batch completed.
    PipelineDown,
}

impl std::fmt::Display for DeployError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DeployError::Plan(e) => e.fmt(f),
            DeployError::BadImage { got, want } => {
                write!(f, "input image has {got} pixels, model wants {want}")
            }
            DeployError::AsymmetricInput(v) => write!(
                f,
                "input pixel {v} outside the symmetric range [-127, 127] — would trip the Conv_3 packing clamp"
            ),
            DeployError::PipelineDown => {
                write!(f, "layer pipeline worker exited before the batch completed")
            }
        }
    }
}

impl std::error::Error for DeployError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            DeployError::Plan(e) => Some(e),
            _ => None,
        }
    }
}

impl From<PlanError> for DeployError {
    fn from(e: PlanError) -> DeployError {
        DeployError::Plan(e)
    }
}

impl Deployment {
    /// Plan and deploy `model` on `dev`.
    pub fn new(
        model: Model,
        weights: Weights,
        dev: &Device,
        clock_mhz: f64,
        policy: &Policy,
    ) -> Result<Deployment, DeployError> {
        let plan = make_plan(&model, dev, clock_mhz, policy)?;
        Ok(Deployment::with_plan(Arc::new(model), Arc::new(weights), plan))
    }

    /// Deploy an already-planned model (the serving tier's constructor:
    /// fleet replicas share one `Arc<Model>`/`Arc<Weights>` and each get
    /// their own pipeline from a plan made under a divided budget).
    pub fn with_plan(model: Arc<Model>, weights: Arc<Weights>, plan: Plan) -> Deployment {
        let metrics = Arc::new(metrics::Metrics::with_layers(model.layers.len()));
        let pipeline =
            Pipeline::start(Arc::clone(&model), Arc::clone(&weights), Arc::clone(&metrics));
        Deployment { model, weights, plan, metrics, pipeline }
    }

    /// Modeled cycles/image per layer from the engine plan (a layer's
    /// engines — e.g. conv + fused ReLU — run pipelined, so the layer's
    /// interval is the max over its engines). Keyed by layer index, the
    /// same key [`metrics::Snapshot::layer_secs`] uses for measured time.
    pub fn layer_cycles(&self) -> Vec<f64> {
        let mut out = vec![0.0f64; self.model.layers.len()];
        for ep in &self.plan.engines {
            out[ep.layer] = out[ep.layer].max(ep.cycles_per_image);
        }
        out
    }

    /// Ingress guard: shape + symmetric-range check (see module docs of
    /// [`crate::cnn`] for why -128 is excluded). Public so the serving
    /// tier can reject bad requests at admission instead of poisoning a
    /// dispatched micro-batch.
    pub fn validate_image(&self, image: &[i64]) -> Result<(), DeployError> {
        validate_image(&self.model, image)
    }

    /// Route this deployment's pipeline-worker layer spans to `tracer`
    /// on track `(pid, base_tid)` — `base_tid` is the replica's own
    /// track ([`crate::trace::tid_of_replica`]); each layer worker takes
    /// a derived track in the replica's tid block. Called by the serving
    /// tier once the replica id exists; re-attaching moves the spans
    /// (a deployment reused by a later server follows that server's
    /// sink and clock).
    pub fn attach_trace(&self, tracer: Tracer, clock: Clock, pid: u64, base_tid: u64) {
        // Context is written before the flag flips so a worker that sees
        // `on` always finds a live context (the mutex orders the reads).
        *self.pipeline.trace.ctx.lock().unwrap() =
            Some(TraceCtx { tracer, clock, pid, tid: base_tid });
        self.pipeline.trace.on.store(true, Ordering::Relaxed);
    }

    /// Stop recording layer spans (workers fall back to one relaxed
    /// load per job).
    pub fn detach_trace(&self) {
        self.pipeline.trace.on.store(false, Ordering::Relaxed);
        *self.pipeline.trace.ctx.lock().unwrap() = None;
    }

    /// Lane-group jobs currently inside this deployment's pipeline. The
    /// retire path of the serving tier polls this to confirm a replica is
    /// quiescent before tearing its pipeline down — unlike the scheduler's
    /// own dispatch counters, it also covers one-shot [`Self::infer_batch`]
    /// callers that never went through a server.
    pub fn in_flight(&self) -> u64 {
        self.pipeline.in_flight.load(Ordering::Acquire)
    }

    /// Serve a batch through the persistent layer pipeline. Returns
    /// per-image logits in submission order. Accepts any slice of
    /// image-like values (`Vec<i64>`, `&[i64]`, ...) so single-image
    /// callers need no copy. Safe to call from any number of threads at
    /// once: batches interleave on the shared workers but every image is
    /// routed back to its own caller by its carried reply channel.
    ///
    /// The batch is packed into lane-group jobs ([`lane_group_width`]):
    /// a serving micro-batch rides the pipeline as a handful of lane
    /// words rather than one channel handoff per image.
    pub fn infer_batch<I>(&self, images: &[I]) -> Result<Vec<Vec<i64>>, DeployError>
    where
        I: AsRef<[i64]> + Sync,
    {
        for img in images {
            self.validate_image(img.as_ref())?;
        }
        let t0 = std::time::Instant::now();
        let tx = self.pipeline.sender().ok_or(DeployError::PipelineDown)?;
        let (reply_tx, reply_rx) = mpsc::channel::<(usize, Vec<i64>)>();
        let group = lane_group_width(images.len(), self.model.layers.len());
        for (gi, chunk) in images.chunks(group).enumerate() {
            let base = gi * group;
            let job = Job {
                tensors: chunk.iter().map(|img| tensorize(&self.model, img.as_ref())).collect(),
                tags: (base..base + chunk.len()).collect(),
                reply: reply_tx.clone(),
            };
            self.pipeline.in_flight.fetch_add(1, Ordering::Release);
            if tx.send(job).is_err() {
                self.pipeline.in_flight.fetch_sub(1, Ordering::Release);
                return Err(DeployError::PipelineDown);
            }
        }
        // Drop our ends so the reply stream terminates even if a worker
        // dies mid-batch (its queued jobs — and their reply clones — drop
        // with it).
        drop(reply_tx);
        drop(tx);
        let mut out = vec![Vec::new(); images.len()];
        let mut got = 0usize;
        while let Ok((tag, logits)) = reply_rx.recv() {
            out[tag] = logits;
            got += 1;
        }
        if got != images.len() {
            return Err(DeployError::PipelineDown);
        }
        self.metrics.record_batch(images.len() as u64, t0.elapsed());
        Ok(out)
    }

    /// Single image convenience (borrows — no per-call image copy).
    pub fn infer_one(&self, image: &[i64]) -> Result<Vec<i64>, DeployError> {
        Ok(self.infer_batch(std::slice::from_ref(&image))?.pop().unwrap())
    }
}

/// Ingress guard against a bare model: shape + symmetric-range check.
/// The serving tier validates at admission against the fleet's shared
/// `Arc<Model>` rather than any particular replica, so admission keeps
/// working while rebalancing swaps replica pipelines in and out.
pub fn validate_image(model: &Model, image: &[i64]) -> Result<(), DeployError> {
    let want = model.in_h * model.in_w * model.in_ch;
    if image.len() != want {
        return Err(DeployError::BadImage { got: image.len(), want });
    }
    if let Some(&bad) = image.iter().find(|&&p| !(-127..=127).contains(&p)) {
        return Err(DeployError::AsymmetricInput(bad));
    }
    Ok(())
}

/// Split a flat ingress image into per-channel planes (stage-0 format).
fn tensorize(model: &Model, img: &[i64]) -> Tensor {
    (0..model.in_ch)
        .map(|c| img[c * model.in_h * model.in_w..(c + 1) * model.in_h * model.in_w].to_vec())
        .collect()
}

/// (h, w) of the tensor *entering* layer `li`.
fn layer_input_geometry(model: &Model, li: usize) -> (usize, usize) {
    let shapes = model.shapes().expect("valid model");
    if li == 0 {
        (model.in_h, model.in_w)
    } else {
        (shapes[li - 1].h, shapes[li - 1].w)
    }
}

/// Apply one layer with the behavioral contract (same code path as
/// [`crate::cnn::infer`], factored per layer for the workers).
fn apply_layer(model: &Model, weights: &Weights, li: usize, input: &Tensor, geom: (usize, usize)) -> Tensor {
    use crate::fixed::sat;
    use crate::ips::fc::fc_ref;
    use crate::ips::pool::maxpool_ref;
    let (cur_h, cur_w) = geom;
    // Weight indices: count conv/fc layers before li.
    let conv_idx = model.layers[..li]
        .iter()
        .filter(|l| matches!(l, Layer::Conv { .. }))
        .count();
    let fc_idx = model.layers[..li].iter().filter(|l| matches!(l, Layer::Fc { .. })).count();
    match &model.layers[li] {
        Layer::Conv { in_ch, out_ch, params, relu } => {
            let k = params.k as usize;
            let (oh, ow) = (cur_h - k + 1, cur_w - k + 1);
            let w = &weights.conv[conv_idx];
            let bias = params.round_bias();
            let shift = params.shift;
            let out_bits = params.out_bits;
            (0..*out_ch)
                .map(|oc| {
                    let mut plane = vec![0i64; oh * ow];
                    for y in 0..oh {
                        for x in 0..ow {
                            let mut sum = 0i64;
                            for ic in 0..*in_ch {
                                // Inline window_ref: dot + bias + requant,
                                // allocation-free (hot loop — §Perf item 5).
                                let plane_in = &input[ic];
                                let coefs = &w[oc][ic];
                                let mut acc = bias;
                                for dy in 0..k {
                                    let row = &plane_in[(y + dy) * cur_w + x..];
                                    let crow = &coefs[dy * k..dy * k + k];
                                    for dx in 0..k {
                                        acc += row[dx] * crow[dx];
                                    }
                                }
                                sum += crate::fixed::requantize(
                                    acc,
                                    shift,
                                    crate::fixed::Round::Truncate,
                                    out_bits,
                                );
                            }
                            let mut v = sat(sum, out_bits);
                            if *relu {
                                v = v.max(0);
                            }
                            plane[y * ow + x] = v;
                        }
                    }
                    plane
                })
                .collect()
        }
        Layer::MaxPool => {
            let (oh, ow) = (cur_h / 2, cur_w / 2);
            input
                .iter()
                .map(|plane| {
                    let mut out = vec![0i64; oh * ow];
                    for y in 0..oh {
                        for x in 0..ow {
                            out[y * ow + x] = maxpool_ref(&[
                                plane[(2 * y) * cur_w + 2 * x],
                                plane[(2 * y) * cur_w + 2 * x + 1],
                                plane[(2 * y + 1) * cur_w + 2 * x],
                                plane[(2 * y + 1) * cur_w + 2 * x + 1],
                            ]);
                        }
                    }
                    out
                })
                .collect()
        }
        Layer::Fc { out_dim, params, relu } => {
            let flat = input.concat();
            let w = &weights.fc[fc_idx];
            let mut out = vec![0i64; *out_dim];
            for (o, row) in w.iter().enumerate() {
                let mut v = fc_ref(params, &flat, row);
                if *relu {
                    v = v.max(0);
                }
                out[o] = v;
            }
            vec![out]
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::data::Dataset;
    use crate::cnn::model::{Model, Weights};
    use crate::fabric::device::by_name;
    use crate::ips::engine::EngineKind;

    fn deploy() -> Deployment {
        let m = Model::lenet_tiny();
        let w = Weights::random(&m, 42);
        let dev = by_name("zcu104").unwrap();
        Deployment::new(m, w, &dev, 200.0, &Policy::adaptive()).unwrap()
    }

    #[test]
    fn lane_group_width_packs_and_keeps_pipeline_full() {
        // Small batches split one group per layer worker; huge batches
        // cap at the simulator lane width; degenerate inputs stay sane.
        assert_eq!(lane_group_width(1, 5), 1);
        assert_eq!(lane_group_width(5, 5), 1);
        assert_eq!(lane_group_width(12, 5), 3);
        assert_eq!(lane_group_width(32, 5), 7);
        assert_eq!(lane_group_width(1000, 5), LANES);
        assert_eq!(lane_group_width(0, 5), 1);
        assert_eq!(lane_group_width(8, 0), 8);
        assert_eq!(lane_group_width(10_000, 1), LANES);
    }

    #[test]
    fn pipeline_matches_reference_inference() {
        let d = deploy();
        let ds = Dataset::generate(12, 3, 16, 16);
        let images: Vec<Vec<i64>> = ds.images.iter().map(|i| i.pix.clone()).collect();
        let got = d.infer_batch(&images).unwrap();
        for (img, logits) in images.iter().zip(&got) {
            let want = crate::cnn::infer::infer(&d.model, &d.weights, img);
            assert_eq!(logits, &want);
        }
    }

    #[test]
    fn order_preserved() {
        let d = deploy();
        let ds = Dataset::generate(8, 5, 16, 16);
        let images: Vec<Vec<i64>> = ds.images.iter().map(|i| i.pix.clone()).collect();
        let a = d.infer_batch(&images).unwrap();
        let b: Vec<Vec<i64>> =
            images.iter().map(|i| d.infer_one(i).unwrap()).collect();
        assert_eq!(a, b);
    }

    #[test]
    fn ingress_guards() {
        let d = deploy();
        assert!(matches!(d.infer_one(&[0; 5]), Err(DeployError::BadImage { .. })));
        let mut img = vec![0i64; 256];
        img[7] = -128;
        assert!(matches!(d.infer_one(&img), Err(DeployError::AsymmetricInput(-128))));
        // The model-level guard is the same check without a deployment.
        assert!(validate_image(&d.model, &img).is_err());
        assert!(validate_image(&d.model, &[0i64; 256]).is_ok());
    }

    #[test]
    fn pipeline_in_flight_settles_to_zero() {
        let d = deploy();
        assert_eq!(d.in_flight(), 0);
        let ds = Dataset::generate(6, 8, 16, 16);
        let images: Vec<Vec<i64>> = ds.images.iter().map(|i| i.pix.clone()).collect();
        d.infer_batch(&images).unwrap();
        // infer_batch waits for every reply, so the gauge must be back to
        // zero by the time it returns — the retire path's drain contract.
        assert_eq!(d.in_flight(), 0);
    }

    #[test]
    fn metrics_accumulate() {
        let d = deploy();
        let ds = Dataset::generate(4, 1, 16, 16);
        let images: Vec<Vec<i64>> = ds.images.iter().map(|i| i.pix.clone()).collect();
        d.infer_batch(&images).unwrap();
        d.infer_batch(&images).unwrap();
        let snap = d.metrics.snapshot();
        assert_eq!(snap.images, 8);
        assert_eq!(snap.batches, 2);
        assert!(snap.wall_secs > 0.0);
    }

    #[test]
    fn concurrent_batches_share_one_pipeline() {
        // The persistent-pipeline contract: many callers, one set of layer
        // workers, no cross-talk, per-caller ordering preserved.
        let d = std::sync::Arc::new(deploy());
        let ds = Dataset::generate(6, 11, 16, 16);
        let images: Vec<Vec<i64>> = ds.images.iter().map(|i| i.pix.clone()).collect();
        let want: Vec<Vec<i64>> = images
            .iter()
            .map(|img| crate::cnn::infer::infer(&d.model, &d.weights, img))
            .collect();
        let mut handles = Vec::new();
        for t in 0..4 {
            let d = std::sync::Arc::clone(&d);
            let images = images.clone();
            handles.push(std::thread::spawn(move || {
                let mut rot = images;
                rot.rotate_left(t);
                (t, d.infer_batch(&rot).unwrap())
            }));
        }
        for h in handles {
            let (t, got) = h.join().unwrap();
            let mut expect = want.clone();
            expect.rotate_left(t);
            assert_eq!(got, expect);
        }
        assert_eq!(d.metrics.snapshot().images, 24);
    }

    #[test]
    fn pipeline_layer_spans_attach_and_detach() {
        use crate::trace::{pid_of_group, tid_of_replica, Clock, Tracer, TIDS_PER_REPLICA};
        let d = deploy();
        let tracer = Tracer::ring(4096);
        d.attach_trace(tracer.clone(), Clock::wall(), pid_of_group(0), tid_of_replica(0));
        let ds = Dataset::generate(4, 9, 16, 16);
        let images: Vec<Vec<i64>> = ds.images.iter().map(|i| i.pix.clone()).collect();
        d.infer_batch(&images).unwrap();
        // Every layer worker recorded at least one span (workers record
        // before forwarding, so all spans exist once the batch returns),
        // each on its own track inside the replica's tid block.
        let evs = tracer.drain();
        for li in 0..d.model.layers.len() {
            assert!(
                evs.iter().any(|e| e.name == format!("layer{li}")),
                "no span for layer {li}"
            );
        }
        let base = tid_of_replica(0);
        for e in &evs {
            assert_eq!(e.cat, "sim");
            assert_eq!(e.pid, pid_of_group(0));
            assert!(e.tid > base && e.tid < base + TIDS_PER_REPLICA, "tid {}", e.tid);
        }
        // Detached: the same traffic records nothing.
        d.detach_trace();
        d.infer_batch(&images).unwrap();
        assert!(tracer.drain().is_empty());
    }

    #[test]
    fn per_layer_timing_keyed_off_engine_plan() {
        let d = deploy();
        let ds = Dataset::generate(6, 2, 16, 16);
        let images: Vec<Vec<i64>> = ds.images.iter().map(|i| i.pix.clone()).collect();
        d.infer_batch(&images).unwrap();
        let snap = d.metrics.snapshot();
        // One measured slot per model layer, and every worker ran.
        assert_eq!(snap.layer_secs.len(), d.model.layers.len());
        assert!(snap.layer_secs.iter().all(|&s| s > 0.0), "{:?}", snap.layer_secs);
        assert!(snap.hottest_layer().is_some());
        // The modeled side uses the same keying: every planned engine maps
        // into the per-layer cycle vector, pool/ReLU included.
        let cycles = d.layer_cycles();
        assert_eq!(cycles.len(), d.model.layers.len());
        assert!(cycles.iter().all(|&c| c > 0.0), "{cycles:?}");
        assert!(d.plan.engines.iter().any(|ep| ep.kind == EngineKind::MaxPool));
    }
}
