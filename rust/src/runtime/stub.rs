//! Stub PJRT/XLA runtime, compiled when the `xla` cargo feature is off.
//!
//! The real runtime (`runtime/mod.rs`) executes the AOT-compiled
//! JAX/Pallas artifacts through the `xla` crate, which is only available
//! in vendored toolchains. This stub keeps the same public surface so the
//! CLI (`acf golden`) and examples always compile; every operation that
//! would touch PJRT reports itself unavailable at run time instead.

use crate::cnn::model::Weights;
use std::path::{Path, PathBuf};

/// Default artifact directory (relative to the repo root).
pub const ARTIFACT_DIR: &str = "artifacts";

/// The seed aot.py bakes (rngport mirrors our xorshift, so
/// `Weights::random(model, AOT_WEIGHT_SEED)` must equal `weights.json`).
pub const AOT_WEIGHT_SEED: u64 = 2025;

const UNAVAILABLE: &str =
    "PJRT/XLA runtime unavailable: acf was built without the 'xla' cargo feature";

/// Locate the artifact directory from the current working directory or
/// its ancestors (same search as the real runtime; loading still needs
/// the `xla` feature).
pub fn find_artifacts() -> Option<PathBuf> {
    let mut dir = std::env::current_dir().ok()?;
    loop {
        let cand = dir.join(ARTIFACT_DIR);
        if cand.join("model.hlo.txt").exists() {
            return Some(cand);
        }
        if !dir.pop() {
            return None;
        }
    }
}

/// Placeholder for the PJRT CPU client.
pub struct PjRtClient;

/// Always errors: the stub cannot host a PJRT client.
pub fn cpu_client() -> Result<PjRtClient, String> {
    Err(UNAVAILABLE.into())
}

/// Placeholder compiled executable.
pub struct Artifact {
    pub name: String,
}

impl Artifact {
    pub fn load(_client: &PjRtClient, _path: &Path) -> Result<Artifact, String> {
        Err(UNAVAILABLE.into())
    }

    pub fn run_i32(&self, _inputs: &[Vec<i32>]) -> Result<Vec<i64>, String> {
        Err(UNAVAILABLE.into())
    }
}

/// Placeholder golden CNN.
pub struct GoldenCnn {
    pub in_len: usize,
    pub out_len: usize,
}

impl GoldenCnn {
    pub fn load(_client: &PjRtClient, _art_dir: &Path) -> Result<GoldenCnn, String> {
        Err(UNAVAILABLE.into())
    }

    pub fn infer(&self, _image: &[i64]) -> Result<Vec<i64>, String> {
        Err(UNAVAILABLE.into())
    }
}

/// Placeholder single-window kernel.
pub struct WindowKernel;

impl WindowKernel {
    pub fn load(_client: &PjRtClient, _art_dir: &Path) -> Result<WindowKernel, String> {
        Err(UNAVAILABLE.into())
    }

    pub fn eval(&self, _win: &[i64; 9], _coef: &[i64; 9]) -> Result<i64, String> {
        Err(UNAVAILABLE.into())
    }
}

/// `weights.json` parsing has no PJRT dependency, so the stub supports it
/// for what-if runs against pre-built artifact directories.
pub fn load_weights(art_dir: &Path) -> Result<Weights, String> {
    let text = std::fs::read_to_string(art_dir.join("weights.json")).map_err(|e| e.to_string())?;
    let json = crate::util::json::Json::parse(&text).map_err(|e| format!("weights.json: {e}"))?;
    Weights::from_json(&json).map_err(|e| format!("weights.json: {e}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_reports_unavailable() {
        assert!(cpu_client().unwrap_err().contains("xla"));
        let c = PjRtClient;
        assert!(Artifact::load(&c, Path::new("x")).is_err());
        assert!(GoldenCnn::load(&c, Path::new("x")).is_err());
        assert!(WindowKernel::load(&c, Path::new("x")).is_err());
    }
}
