//! PJRT/XLA runtime — loads the AOT artifacts `python/compile/aot.py`
//! produced and executes them from Rust. This is the system's *golden
//! numeric reference*: the JAX/Pallas model, compiled once at build time,
//! never Python at run time.
//!
//! Pattern follows /opt/xla-example/load_hlo: HLO **text** →
//! `HloModuleProto::from_text_file` → compile on the CPU PJRT client →
//! execute. Inputs/outputs are int32 (int8-range values) because the xla
//! crate's `Literal` constructors cover i32 natively.

use crate::cnn::model::Weights;
use anyhow::{anyhow, Context, Result};
use std::path::{Path, PathBuf};

/// Default artifact directory (relative to the repo root).
pub const ARTIFACT_DIR: &str = "artifacts";

/// Locate the artifact directory from the current working directory or
/// its ancestors (tests run from the crate root; binaries may not).
pub fn find_artifacts() -> Option<PathBuf> {
    let mut dir = std::env::current_dir().ok()?;
    loop {
        let cand = dir.join(ARTIFACT_DIR);
        if cand.join("model.hlo.txt").exists() {
            return Some(cand);
        }
        if !dir.pop() {
            return None;
        }
    }
}

/// A compiled XLA executable with fixed input arity.
pub struct Artifact {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

/// The PJRT CPU client (one per process is plenty).
pub fn cpu_client() -> Result<xla::PjRtClient> {
    xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))
}

impl Artifact {
    /// Load + compile an HLO-text artifact.
    pub fn load(client: &xla::PjRtClient, path: &Path) -> Result<Artifact> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .map_err(|e| anyhow!("parse {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp).map_err(|e| anyhow!("compile {path:?}: {e:?}"))?;
        Ok(Artifact { exe, name: path.file_name().unwrap().to_string_lossy().into_owned() })
    }

    /// Execute with i32 vector inputs; returns the first tuple element as
    /// i64s (aot.py lowers with return_tuple=True).
    pub fn run_i32(&self, inputs: &[Vec<i32>]) -> Result<Vec<i64>> {
        let literals: Vec<xla::Literal> =
            inputs.iter().map(|v| xla::Literal::vec1(v.as_slice())).collect();
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("execute {}: {e:?}", self.name))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch {}: {e:?}", self.name))?;
        let out = result.to_tuple1().map_err(|e| anyhow!("untuple {}: {e:?}", self.name))?;
        let vals = out.to_vec::<i32>().map_err(|e| anyhow!("to_vec {}: {e:?}", self.name))?;
        Ok(vals.into_iter().map(|v| v as i64).collect())
    }
}

/// The golden CNN: the AOT-compiled lenet-tiny with baked weights.
pub struct GoldenCnn {
    artifact: Artifact,
    pub in_len: usize,
    pub out_len: usize,
}

impl GoldenCnn {
    pub fn load(client: &xla::PjRtClient, art_dir: &Path) -> Result<GoldenCnn> {
        let artifact = Artifact::load(client, &art_dir.join("model.hlo.txt"))?;
        Ok(GoldenCnn { artifact, in_len: 256, out_len: 10 })
    }

    /// Golden logits for one image.
    pub fn infer(&self, image: &[i64]) -> Result<Vec<i64>> {
        if image.len() != self.in_len {
            return Err(anyhow!("image len {} != {}", image.len(), self.in_len));
        }
        let x: Vec<i32> = image.iter().map(|&v| v as i32).collect();
        let out = self.artifact.run_i32(&[x])?;
        if out.len() != self.out_len {
            return Err(anyhow!("logits len {} != {}", out.len(), self.out_len));
        }
        Ok(out)
    }
}

/// The single-window kernel artifact (IP pass semantics cross-check).
pub struct WindowKernel {
    artifact: Artifact,
}

impl WindowKernel {
    pub fn load(client: &xla::PjRtClient, art_dir: &Path) -> Result<WindowKernel> {
        Ok(WindowKernel { artifact: Artifact::load(client, &art_dir.join("window_k3_w8.hlo.txt"))? })
    }

    pub fn eval(&self, win: &[i64; 9], coef: &[i64; 9]) -> Result<i64> {
        let w: Vec<i32> = win.iter().map(|&v| v as i32).collect();
        let c: Vec<i32> = coef.iter().map(|&v| v as i32).collect();
        let out = self.artifact.run_i32(&[w, c])?;
        Ok(out[0])
    }
}

/// Load `weights.json` written by aot.py.
pub fn load_weights(art_dir: &Path) -> Result<Weights> {
    let text = std::fs::read_to_string(art_dir.join("weights.json"))?;
    let json = crate::util::json::Json::parse(&text).map_err(|e| anyhow!("weights.json: {e}"))?;
    Weights::from_json(&json).map_err(|e| anyhow!("weights.json: {e}"))
}

/// The seed aot.py bakes (rngport mirrors our xorshift, so
/// `Weights::random(model, AOT_WEIGHT_SEED)` must equal `weights.json`).
pub const AOT_WEIGHT_SEED: u64 = 2025;
