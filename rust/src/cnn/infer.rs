//! Reference fixed-point inference — the behavioral golden model.
//!
//! Implements the layer arithmetic contract from the module docs using
//! [`ConvParams::window_ref`] / [`fc_ref`] / [`maxpool_ref`], i.e. the
//! exact per-window semantics the IP netlists implement. The coordinator's
//! deployed inference and the XLA artifact must both match this
//! bit-for-bit.

use super::model::{Layer, Model, Weights};
use crate::fixed::sat;
use crate::ips::fc::fc_ref;
use crate::ips::pool::maxpool_ref;

/// Activation tensor: channel-major `[ch][h*w]`.
pub type Tensor = Vec<Vec<i64>>;

/// Run inference, returning the logits (final activation, flattened).
pub fn infer(model: &Model, weights: &Weights, image: &[i64]) -> Vec<i64> {
    infer_trace(model, weights, image).pop().expect("nonempty model").concat()
}

/// Run inference, returning EVERY layer's output tensor (for debugging and
/// cross-layer comparison tests).
pub fn infer_trace(model: &Model, weights: &Weights, image: &[i64]) -> Vec<Tensor> {
    assert_eq!(image.len(), model.in_h * model.in_w * model.in_ch);
    let mut cur: Tensor = (0..model.in_ch)
        .map(|c| image[c * model.in_h * model.in_w..(c + 1) * model.in_h * model.in_w].to_vec())
        .collect();
    let mut cur_h = model.in_h;
    let mut cur_w = model.in_w;
    let mut conv_idx = 0usize;
    let mut fc_idx = 0usize;
    let mut trace = Vec::new();
    for layer in &model.layers {
        match layer {
            Layer::Conv { in_ch, out_ch, params, relu } => {
                let k = params.k as usize;
                let (oh, ow) = (cur_h - k + 1, cur_w - k + 1);
                let w = &weights.conv[conv_idx];
                let mut out: Tensor = vec![vec![0; oh * ow]; *out_ch];
                for oc in 0..*out_ch {
                    for y in 0..oh {
                        for x in 0..ow {
                            let mut sum = 0i64;
                            for ic in 0..*in_ch {
                                let win = window(&cur[ic], cur_w, x, y, k);
                                sum += params.window_ref(&win, &w[oc][ic]);
                            }
                            // Channel-partial sum saturates at out_bits.
                            let mut v = sat(sum, params.out_bits);
                            if *relu {
                                v = v.max(0);
                            }
                            out[oc][y * ow + x] = v;
                        }
                    }
                }
                cur = out;
                cur_h = oh;
                cur_w = ow;
                conv_idx += 1;
            }
            Layer::MaxPool => {
                let (oh, ow) = (cur_h / 2, cur_w / 2);
                let mut out: Tensor = vec![vec![0; oh * ow]; cur.len()];
                for (c, plane) in cur.iter().enumerate() {
                    for y in 0..oh {
                        for x in 0..ow {
                            let vals = [
                                plane[(2 * y) * cur_w + 2 * x],
                                plane[(2 * y) * cur_w + 2 * x + 1],
                                plane[(2 * y + 1) * cur_w + 2 * x],
                                plane[(2 * y + 1) * cur_w + 2 * x + 1],
                            ];
                            out[c][y * ow + x] = maxpool_ref(&vals);
                        }
                    }
                }
                cur = out;
                cur_h = oh;
                cur_w = ow;
            }
            Layer::Fc { out_dim, params, relu } => {
                let flat = flatten(&cur);
                let w = &weights.fc[fc_idx];
                let mut out = vec![0i64; *out_dim];
                for (o, row) in w.iter().enumerate() {
                    let mut v = fc_ref(params, &flat, row);
                    if *relu {
                        v = v.max(0);
                    }
                    out[o] = v;
                }
                cur = vec![out];
                cur_h = 1;
                cur_w = 1;
                fc_idx += 1;
            }
        }
        trace.push(cur.clone());
    }
    trace
}

/// Extract a K×K window at (x, y) from a row-major plane.
pub fn window(plane: &[i64], width: usize, x: usize, y: usize, k: usize) -> Vec<i64> {
    let mut out = Vec::with_capacity(k * k);
    for dy in 0..k {
        for dx in 0..k {
            out.push(plane[(y + dy) * width + (x + dx)]);
        }
    }
    out
}

/// Flatten channel-major tensor in `ch, y, x` order (the order `aot.py`
/// mirrors for the FC weights).
pub fn flatten(t: &Tensor) -> Vec<i64> {
    t.concat()
}

/// Argmax of logits (ties: lowest index).
pub fn argmax(logits: &[i64]) -> usize {
    logits
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.cmp(b.1).then(b.0.cmp(&a.0)))
        .map(|(i, _)| i)
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::data::Dataset;
    use crate::cnn::model::{Model, Weights};

    #[test]
    fn shapes_flow_through() {
        let m = Model::lenet_tiny();
        let w = Weights::random(&m, 1);
        let ds = Dataset::generate(3, 2, 16, 16);
        let trace = infer_trace(&m, &w, &ds.images[0].pix);
        assert_eq!(trace.len(), 5);
        assert_eq!(trace[0].len(), 4); // conv1: 4 channels
        assert_eq!(trace[0][0].len(), 14 * 14);
        assert_eq!(trace[4][0].len(), 10); // logits
    }

    #[test]
    fn outputs_respect_out_bits() {
        let m = Model::lenet_tiny();
        let w = Weights::random(&m, 9);
        let ds = Dataset::generate(5, 4, 16, 16);
        for img in &ds.images {
            let trace = infer_trace(&m, &w, &img.pix);
            for t in &trace {
                for plane in t {
                    assert!(plane.iter().all(|&v| (-128..=127).contains(&v)));
                }
            }
        }
    }

    #[test]
    fn relu_layers_nonnegative() {
        let m = Model::lenet_tiny();
        let w = Weights::random(&m, 5);
        let ds = Dataset::generate(2, 8, 16, 16);
        let trace = infer_trace(&m, &w, &ds.images[0].pix);
        for plane in &trace[0] {
            assert!(plane.iter().all(|&v| v >= 0), "conv+relu output");
        }
        for plane in &trace[2] {
            assert!(plane.iter().all(|&v| v >= 0));
        }
    }

    #[test]
    fn deterministic() {
        let m = Model::lenet_tiny();
        let w = Weights::random(&m, 5);
        let ds = Dataset::generate(1, 8, 16, 16);
        assert_eq!(infer(&m, &w, &ds.images[0].pix), infer(&m, &w, &ds.images[0].pix));
    }

    #[test]
    fn window_and_argmax() {
        let plane: Vec<i64> = (0..16).collect(); // 4x4
        assert_eq!(window(&plane, 4, 1, 1, 2), vec![5, 6, 9, 10]);
        assert_eq!(argmax(&[1, 5, 5, 2]), 1);
        assert_eq!(argmax(&[-3, -1]), 1);
    }
}
