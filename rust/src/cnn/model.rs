//! CNN model description (the config system's main payload) and weights.

use crate::ips::ConvParams;
use crate::util::json::{obj, Json, JsonError};
use crate::util::rng::Rng;

/// One layer of the network.
#[derive(Debug, Clone, PartialEq)]
pub enum Layer {
    /// 2-D convolution, `valid` padding, stride 1, optional fused ReLU.
    Conv { in_ch: usize, out_ch: usize, params: ConvParams, relu: bool },
    /// 2×2 max-pool, stride 2.
    MaxPool,
    /// Fully connected over the flattened input, optional fused ReLU.
    Fc { out_dim: usize, params: ConvParams, relu: bool },
}

/// Elements pooled per [`Layer::MaxPool`] output (2×2, stride 2). The
/// planner profiles a comparator tree of exactly this size; keep in sync
/// with [`Model::shapes`]'s dimension halving and the coordinator's 2×2
/// window indexing if pooling geometry is ever generalized.
pub const POOL_WINDOW: u32 = 4;

/// A model: input geometry plus the layer stack.
#[derive(Debug, Clone, PartialEq)]
pub struct Model {
    pub name: String,
    pub in_h: usize,
    pub in_w: usize,
    pub in_ch: usize,
    pub layers: Vec<Layer>,
}

/// Registry names resolvable by [`model_by_name`] — the built-in model zoo
/// that multi-model serving composes fleets over. Catalog-file models and
/// report-only variants layer on top of this list at the CLI.
pub const MODEL_ZOO: &[&str] = &["lenet-tiny", "lenet-wide-2x", "lenet-wide-4x"];

/// Resolve a built-in zoo model by name. Accepts the canonical names in
/// [`MODEL_ZOO`] plus the CLI shorthands `lenet-wide` (→ 2x), `lenet-wide2`,
/// and `lenet-wide4`. Returns `None` for unknown names so callers can fall
/// back to catalogs or model files.
pub fn model_by_name(name: &str) -> Option<Model> {
    match name {
        "lenet-tiny" => Some(Model::lenet_tiny()),
        "lenet-wide" | "lenet-wide2" | "lenet-wide-2x" => Some(Model::lenet_wide(2)),
        "lenet-wide4" | "lenet-wide-4x" => Some(Model::lenet_wide(4)),
        _ => None,
    }
}

/// Shape of an activation tensor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Shape {
    pub h: usize,
    pub w: usize,
    pub ch: usize,
}

impl Shape {
    pub fn numel(&self) -> usize {
        self.h * self.w * self.ch
    }
}

impl Model {
    /// The e2e driver's network: a LeNet-style digit classifier sized for
    /// the 16×16 synthetic corpus.
    /// conv(1→4,3×3)+relu → pool → conv(4→8,3×3)+relu → pool → fc(→10).
    pub fn lenet_tiny() -> Model {
        let p = ConvParams::paper_8bit();
        Model {
            name: "lenet-tiny".into(),
            in_h: 16,
            in_w: 16,
            in_ch: 1,
            layers: vec![
                Layer::Conv { in_ch: 1, out_ch: 4, params: p, relu: true },
                Layer::MaxPool,
                Layer::Conv { in_ch: 4, out_ch: 8, params: p, relu: true },
                Layer::MaxPool,
                Layer::Fc { out_dim: 10, params: p, relu: false },
            ],
        }
    }

    /// A deeper variant for scalability sweeps.
    pub fn lenet_wide(width_mult: usize) -> Model {
        let p = ConvParams::paper_8bit();
        let m = width_mult.max(1);
        Model {
            name: format!("lenet-wide-{m}x"),
            in_h: 16,
            in_w: 16,
            in_ch: 1,
            layers: vec![
                Layer::Conv { in_ch: 1, out_ch: 4 * m, params: p, relu: true },
                Layer::MaxPool,
                Layer::Conv { in_ch: 4 * m, out_ch: 8 * m, params: p, relu: true },
                Layer::MaxPool,
                Layer::Fc { out_dim: 10, params: p, relu: false },
            ],
        }
    }

    /// Per-layer output shapes (validates geometry).
    pub fn shapes(&self) -> Result<Vec<Shape>, String> {
        let mut cur = Shape { h: self.in_h, w: self.in_w, ch: self.in_ch };
        let mut out = Vec::new();
        for (i, layer) in self.layers.iter().enumerate() {
            cur = match layer {
                Layer::Conv { in_ch, out_ch, params, .. } => {
                    if *in_ch != cur.ch {
                        return Err(format!("layer {i}: in_ch {} != incoming {}", in_ch, cur.ch));
                    }
                    let k = params.k as usize;
                    if cur.h < k || cur.w < k {
                        return Err(format!("layer {i}: {k}x{k} kernel larger than input"));
                    }
                    Shape { h: cur.h - k + 1, w: cur.w - k + 1, ch: *out_ch }
                }
                Layer::MaxPool => {
                    if cur.h < 2 || cur.w < 2 {
                        return Err(format!("layer {i}: pool on degenerate input"));
                    }
                    Shape { h: cur.h / 2, w: cur.w / 2, ch: cur.ch }
                }
                Layer::Fc { out_dim, .. } => Shape { h: 1, w: 1, ch: *out_dim },
            };
            out.push(cur);
        }
        Ok(out)
    }

    pub fn to_json(&self) -> Json {
        let layers: Vec<Json> = self
            .layers
            .iter()
            .map(|l| match l {
                Layer::Conv { in_ch, out_ch, params, relu } => obj([
                    ("type", "conv".into()),
                    ("in_ch", (*in_ch).into()),
                    ("out_ch", (*out_ch).into()),
                    ("params", params.to_json()),
                    ("relu", (*relu).into()),
                ]),
                Layer::MaxPool => obj([("type", "maxpool".into())]),
                Layer::Fc { out_dim, params, relu } => obj([
                    ("type", "fc".into()),
                    ("out_dim", (*out_dim).into()),
                    ("params", params.to_json()),
                    ("relu", (*relu).into()),
                ]),
            })
            .collect();
        obj([
            ("name", self.name.as_str().into()),
            ("in_h", self.in_h.into()),
            ("in_w", self.in_w.into()),
            ("in_ch", self.in_ch.into()),
            ("layers", Json::Arr(layers)),
        ])
    }

    pub fn from_json(v: &Json) -> Result<Model, JsonError> {
        let layers = v
            .get("layers")?
            .as_arr()?
            .iter()
            .map(|l| {
                Ok(match l.get("type")?.as_str()? {
                    "conv" => Layer::Conv {
                        in_ch: l.get("in_ch")?.as_usize()?,
                        out_ch: l.get("out_ch")?.as_usize()?,
                        params: ConvParams::from_json(l.get("params")?)?,
                        relu: l.get("relu")?.as_bool()?,
                    },
                    "maxpool" => Layer::MaxPool,
                    "fc" => Layer::Fc {
                        out_dim: l.get("out_dim")?.as_usize()?,
                        params: ConvParams::from_json(l.get("params")?)?,
                        relu: l.get("relu")?.as_bool()?,
                    },
                    other => return Err(JsonError::Access(format!("unknown layer type '{other}'"))),
                })
            })
            .collect::<Result<Vec<_>, JsonError>>()?;
        Ok(Model {
            name: v.get("name")?.as_str()?.to_string(),
            in_h: v.get("in_h")?.as_usize()?,
            in_w: v.get("in_w")?.as_usize()?,
            in_ch: v.get("in_ch")?.as_usize()?,
            layers,
        })
    }
}

/// Weights for a model: conv filters indexed `[layer][out_ch][in_ch][k²]`,
/// FC matrices `[layer][out][in]`. Values are symmetric int8-style
/// (`[-(2^(b-1)-1), 2^(b-1)-1]`) so the `Conv_3` clamp can never fire.
#[derive(Debug, Clone, PartialEq)]
pub struct Weights {
    pub conv: Vec<Vec<Vec<Vec<i64>>>>,
    pub fc: Vec<Vec<Vec<i64>>>,
}

impl Weights {
    /// Deterministic random weights (symmetric range).
    pub fn random(model: &Model, seed: u64) -> Weights {
        let mut rng = Rng::new(seed);
        let mut conv = Vec::new();
        let mut fc = Vec::new();
        let shapes = model.shapes().expect("valid model");
        let mut cur = Shape { h: model.in_h, w: model.in_w, ch: model.in_ch };
        for (i, layer) in model.layers.iter().enumerate() {
            match layer {
                Layer::Conv { in_ch, out_ch, params, .. } => {
                    let taps = params.taps() as usize;
                    let hi = (1i64 << (params.coef_bits - 1)) - 1;
                    conv.push(
                        (0..*out_ch)
                            .map(|_| {
                                (0..*in_ch)
                                    .map(|_| (0..taps).map(|_| rng.range_i64(-hi, hi)).collect())
                                    .collect()
                            })
                            .collect(),
                    );
                }
                Layer::Fc { out_dim, params, .. } => {
                    let in_dim = cur.numel();
                    let hi = (1i64 << (params.coef_bits - 1)) - 1;
                    fc.push(
                        (0..*out_dim)
                            .map(|_| (0..in_dim).map(|_| rng.range_i64(-hi, hi)).collect())
                            .collect(),
                    );
                }
                Layer::MaxPool => {}
            }
            cur = shapes[i];
        }
        Weights { conv, fc }
    }

    pub fn to_json(&self) -> Json {
        let conv: Vec<Json> = self
            .conv
            .iter()
            .map(|l| {
                Json::Arr(
                    l.iter()
                        .map(|oc| {
                            Json::Arr(
                                oc.iter()
                                    .map(|ic| Json::Arr(ic.iter().map(|&v| v.into()).collect()))
                                    .collect(),
                            )
                        })
                        .collect(),
                )
            })
            .collect();
        let fc: Vec<Json> = self
            .fc
            .iter()
            .map(|l| {
                Json::Arr(
                    l.iter()
                        .map(|row| Json::Arr(row.iter().map(|&v| v.into()).collect()))
                        .collect(),
                )
            })
            .collect();
        obj([("conv", Json::Arr(conv)), ("fc", Json::Arr(fc))])
    }

    pub fn from_json(v: &Json) -> Result<Weights, JsonError> {
        fn vec_i64(j: &Json) -> Result<Vec<i64>, JsonError> {
            j.as_arr()?.iter().map(|x| x.as_i64()).collect()
        }
        let conv = v
            .get("conv")?
            .as_arr()?
            .iter()
            .map(|l| {
                l.as_arr()?
                    .iter()
                    .map(|oc| oc.as_arr()?.iter().map(vec_i64).collect())
                    .collect()
            })
            .collect::<Result<_, _>>()?;
        let fc = v
            .get("fc")?
            .as_arr()?
            .iter()
            .map(|l| l.as_arr()?.iter().map(vec_i64).collect())
            .collect::<Result<_, _>>()?;
        Ok(Weights { conv, fc })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lenet_shapes() {
        let m = Model::lenet_tiny();
        let s = m.shapes().unwrap();
        assert_eq!(s[0], Shape { h: 14, w: 14, ch: 4 }); // conv 16->14
        assert_eq!(s[1], Shape { h: 7, w: 7, ch: 4 }); // pool
        assert_eq!(s[2], Shape { h: 5, w: 5, ch: 8 }); // conv
        assert_eq!(s[3], Shape { h: 2, w: 2, ch: 8 }); // pool
        assert_eq!(s[4], Shape { h: 1, w: 1, ch: 10 }); // fc
    }

    #[test]
    fn bad_geometry_rejected() {
        let mut m = Model::lenet_tiny();
        m.in_h = 2;
        assert!(m.shapes().is_err());
        let mut m2 = Model::lenet_tiny();
        if let Layer::Conv { in_ch, .. } = &mut m2.layers[0] {
            *in_ch = 3;
        }
        assert!(m2.shapes().is_err());
    }

    #[test]
    fn registry_resolves_zoo_names_and_shorthands() {
        for name in MODEL_ZOO {
            let m = model_by_name(name).expect("zoo name resolves");
            assert_eq!(&m.name, name, "canonical zoo names round-trip");
            assert!(m.shapes().is_ok());
        }
        assert_eq!(model_by_name("lenet-wide").unwrap().name, "lenet-wide-2x");
        assert_eq!(model_by_name("lenet-wide2").unwrap().name, "lenet-wide-2x");
        assert_eq!(model_by_name("lenet-wide4").unwrap().name, "lenet-wide-4x");
        assert!(model_by_name("resnet-900").is_none());
    }

    #[test]
    fn model_json_roundtrip() {
        let m = Model::lenet_tiny();
        let back = Model::from_json(&m.to_json()).unwrap();
        assert_eq!(back, m);
    }

    #[test]
    fn weights_symmetric_and_roundtrip() {
        let m = Model::lenet_tiny();
        let w = Weights::random(&m, 42);
        assert_eq!(w.conv.len(), 2);
        assert_eq!(w.fc.len(), 1);
        assert_eq!(w.conv[0].len(), 4);
        assert_eq!(w.conv[1][0].len(), 4);
        assert_eq!(w.fc[0].len(), 10);
        assert_eq!(w.fc[0][0].len(), 2 * 2 * 8);
        for l in &w.conv {
            for oc in l {
                for ic in oc {
                    assert!(ic.iter().all(|&v| (-127..=127).contains(&v)));
                }
            }
        }
        let back = Weights::from_json(&w.to_json()).unwrap();
        assert_eq!(back, w);
        // Deterministic.
        assert_eq!(Weights::random(&m, 42), w);
        assert_ne!(Weights::random(&m, 43), w);
    }
}
