//! Synthetic digit corpus — the e2e workload.
//!
//! Procedurally rasterized seven-segment-style digits on a 16×16 canvas
//! with jitter and noise, quantized to the symmetric int8 range
//! `[-127, 127]` (background negative, strokes positive). No external
//! dataset exists in this offline environment; this exercises the same
//! conv/pool/fc code paths a real corpus would.

use crate::util::rng::Rng;

/// One image: row-major `h × w`, single channel, values in `[-127, 127]`.
#[derive(Debug, Clone, PartialEq)]
pub struct Image {
    pub h: usize,
    pub w: usize,
    pub pix: Vec<i64>,
    pub label: u8,
}

/// Seven-segment truth table per digit: segments A..G.
///  A: top, B: top-right, C: bottom-right, D: bottom, E: bottom-left,
///  F: top-left, G: middle.
const SEGMENTS: [[bool; 7]; 10] = [
    [true, true, true, true, true, true, false],     // 0
    [false, true, true, false, false, false, false], // 1
    [true, true, false, true, true, false, true],    // 2
    [true, true, true, true, false, false, true],    // 3
    [false, true, true, false, false, true, true],   // 4
    [true, false, true, true, false, true, true],    // 5
    [true, false, true, true, true, true, true],     // 6
    [true, true, true, false, false, false, false],  // 7
    [true, true, true, true, true, true, true],      // 8
    [true, true, true, true, false, true, true],     // 9
];

/// Render one digit with stroke jitter and pixel noise.
pub fn render_digit(digit: u8, rng: &mut Rng, h: usize, w: usize) -> Image {
    assert!(digit < 10);
    assert!(h >= 12 && w >= 10, "canvas too small");
    let bg = -100i64 + rng.range_i64(-20, 20);
    let fg = 100i64 + rng.range_i64(-20, 20);
    let mut pix = vec![bg; h * w];
    // Digit bounding box with jitter.
    let x0 = 2 + rng.index(w - 9);
    let y0 = 1 + rng.index(h - 11);
    let dw = 6;
    let dh = 10;
    let segs = SEGMENTS[digit as usize];
    let stroke = |x: usize, y: usize, horiz: bool, len: usize, pix: &mut Vec<i64>| {
        for i in 0..len {
            let (px, py) = if horiz { (x + i, y) } else { (x, y + i) };
            if px < w && py < h {
                pix[py * w + px] = fg;
                // 2-pixel-wide strokes for visibility after 3x3 convs.
                let (qx, qy) = if horiz { (px, py + 1) } else { (px + 1, py) };
                if qx < w && qy < h {
                    pix[qy * w + qx] = fg;
                }
            }
        }
    };
    if segs[0] {
        stroke(x0, y0, true, dw, &mut pix); // A
    }
    if segs[1] {
        stroke(x0 + dw - 1, y0, false, dh / 2, &mut pix); // B
    }
    if segs[2] {
        stroke(x0 + dw - 1, y0 + dh / 2, false, dh / 2, &mut pix); // C
    }
    if segs[3] {
        stroke(x0, y0 + dh - 1, true, dw, &mut pix); // D
    }
    if segs[4] {
        stroke(x0, y0 + dh / 2, false, dh / 2, &mut pix); // E
    }
    if segs[5] {
        stroke(x0, y0, false, dh / 2, &mut pix); // F
    }
    if segs[6] {
        stroke(x0, y0 + dh / 2 - 1, true, dw, &mut pix); // G
    }
    // Salt noise.
    for p in pix.iter_mut() {
        if rng.chance(0.02) {
            *p = rng.range_i64(-127, 127);
        }
        *p = (*p).clamp(-127, 127);
    }
    Image { h, w, pix, label: digit }
}

/// A deterministic dataset of `n` images.
pub struct Dataset {
    pub images: Vec<Image>,
}

impl Dataset {
    pub fn generate(n: usize, seed: u64, h: usize, w: usize) -> Dataset {
        let mut rng = Rng::new(seed);
        let images = (0..n).map(|i| render_digit((i % 10) as u8, &mut rng, h, w)).collect();
        Dataset { images }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_in_range() {
        let a = Dataset::generate(20, 7, 16, 16);
        let b = Dataset::generate(20, 7, 16, 16);
        assert_eq!(a.images.len(), 20);
        for (x, y) in a.images.iter().zip(&b.images) {
            assert_eq!(x, y);
        }
        for img in &a.images {
            assert_eq!(img.pix.len(), 256);
            assert!(img.pix.iter().all(|&p| (-127..=127).contains(&p)), "symmetric range");
        }
    }

    #[test]
    fn digits_are_distinguishable() {
        // Different digits differ in many pixels (same rng stream
        // position via fresh seeds).
        let mut r1 = Rng::new(1);
        let mut r2 = Rng::new(1);
        let d1 = render_digit(1, &mut r1, 16, 16);
        let d8 = render_digit(8, &mut r2, 16, 16);
        let diff = d1.pix.iter().zip(&d8.pix).filter(|(a, b)| a != b).count();
        assert!(diff > 12, "1 vs 8 differ in {diff} px");
    }

    #[test]
    fn labels_cycle() {
        let d = Dataset::generate(25, 3, 16, 16);
        assert_eq!(d.images[0].label, 0);
        assert_eq!(d.images[13].label, 3);
    }
}
