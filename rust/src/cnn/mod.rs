//! Quantized CNN model graph, synthetic workload, and the reference
//! fixed-point inference the whole stack is verified against.
//!
//! ## Layer arithmetic contract
//!
//! Every layer in this library agrees on ONE set of semantics, chosen so
//! the conv IPs implement it exactly and the Pallas kernels mirror it:
//!
//! * A conv output channel is `sat_out( Σ_c requant(window_dot(x_c, w_c)) )`
//!   — each input-channel window is processed by an IP *pass* (requantized
//!   at `out_bits`), and channel partials are summed and saturated by the
//!   layer engine. ReLU optionally follows.
//! * Pixels entering conv layers never hold the most-negative code
//!   (images are generated in `[-127, 127]` and intermediate activations
//!   are post-ReLU), so `Conv_3`'s high-lane clamp never fires and any IP
//!   mix yields bit-identical results.
//! * FC neurons use [`crate::ips::fc::fc_ref`] semantics; max-pool is
//!   exact.

pub mod data;
pub mod infer;
pub mod model;

pub use data::{render_digit, Dataset};
pub use infer::{infer, infer_trace};
pub use model::{model_by_name, Layer, Model, Weights, MODEL_ZOO};
