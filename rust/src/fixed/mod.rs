//! Fixed-point arithmetic contract shared by every layer of the stack.
//!
//! The paper's IPs use signed fixed-point operands ("8-bit fixed-point
//! data"), int-width-parameterized multipliers, wide accumulators, and a
//! requantization step (arithmetic right shift with round-to-nearest-even
//! optional, then saturation) when an accumulator is narrowed back to the
//! activation width. The Pallas kernels (`python/compile/kernels/`), the
//! behavioral IP models ([`crate::ips`]), and the netlist simulator must
//! agree bit-for-bit on these semantics; this module is the single source
//! of truth on the Rust side and `ref.py` mirrors it in Python.

pub mod pack;

/// A signed fixed-point *format*: `bits` total width (two's complement),
/// `frac` fractional bits. The IPs treat values as integers; `frac` only
/// matters for human-readable scaling and requantization shift amounts.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Format {
    pub bits: u32,
    pub frac: u32,
}

impl Format {
    pub const fn new(bits: u32, frac: u32) -> Self {
        assert!(bits >= 2 && bits <= 48);
        assert!(frac < bits);
        Format { bits, frac }
    }

    /// Q7.0 — the paper's experimental operand format ("8-bit fixed-point").
    pub const Q8: Format = Format::new(8, 0);

    /// Smallest representable value.
    pub const fn min(&self) -> i64 {
        -(1i64 << (self.bits - 1))
    }

    /// Largest representable value.
    pub const fn max(&self) -> i64 {
        (1i64 << (self.bits - 1)) - 1
    }

    /// Does `v` fit this format?
    pub fn contains(&self, v: i64) -> bool {
        v >= self.min() && v <= self.max()
    }

    /// Real value of the integer representation.
    pub fn to_real(&self, v: i64) -> f64 {
        v as f64 / (1u64 << self.frac) as f64
    }

    /// Quantize a real value into this format (round-to-nearest, ties away
    /// from zero, then saturate) — used when importing float weights.
    pub fn quantize(&self, x: f64) -> i64 {
        let scaled = x * (1u64 << self.frac) as f64;
        let rounded = if scaled >= 0.0 { (scaled + 0.5).floor() } else { (scaled - 0.5).ceil() };
        sat(rounded as i64, self.bits)
    }
}

/// Saturate `v` into a signed `bits`-bit range.
pub fn sat(v: i64, bits: u32) -> i64 {
    let lo = -(1i64 << (bits - 1));
    let hi = (1i64 << (bits - 1)) - 1;
    v.clamp(lo, hi)
}

/// Wrap `v` into signed `bits`-bit two's complement (what a hardware
/// register without saturation logic does).
pub fn wrap(v: i64, bits: u32) -> i64 {
    debug_assert!(bits >= 1 && bits <= 63);
    let m = 1i64 << bits;
    let r = v.rem_euclid(m);
    if r >= m / 2 {
        r - m
    } else {
        r
    }
}

/// Rounding mode for requantization. The IPs implement `Truncate`
/// (cheapest: drop LSBs) and `NearestEven` (one extra adder); the paper's
/// "optimal performance" fixed-point claim maps to Truncate.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Round {
    /// Arithmetic shift right — floor division by 2^shift.
    Truncate,
    /// Round half to even (convergent rounding, DSP48E2 `RND` pattern).
    NearestEven,
}

/// Requantize an accumulator: shift right by `shift` with rounding mode
/// `round`, then saturate into `out_bits`.
pub fn requantize(acc: i64, shift: u32, round: Round, out_bits: u32) -> i64 {
    let shifted = match round {
        Round::Truncate => acc >> shift,
        Round::NearestEven => {
            if shift == 0 {
                acc
            } else {
                let floor = acc >> shift;
                let rem = acc - (floor << shift);
                let half = 1i64 << (shift - 1);
                if rem > half || (rem == half && (floor & 1) == 1) {
                    floor + 1
                } else {
                    floor
                }
            }
        }
    };
    sat(shifted, out_bits)
}

/// Exact widening multiply of two sign-limited operands; panics in debug
/// if operands exceed their declared widths (the IP contract).
pub fn mul(a: i64, a_bits: u32, b: i64, b_bits: u32) -> i64 {
    debug_assert!(Format::new(a_bits.max(2), 0).contains(a), "a={a} !fit {a_bits}b");
    debug_assert!(Format::new(b_bits.max(2), 0).contains(b), "b={b} !fit {b_bits}b");
    a * b
}

/// Accumulator width needed for `n` products of `a_bits`×`b_bits`
/// operands without overflow: product needs `a+b-1` magnitude bits plus
/// sign; summing `n` adds `ceil(log2 n)`.
pub fn acc_bits(a_bits: u32, b_bits: u32, n_products: u32) -> u32 {
    let prod = a_bits + b_bits; // includes sign growth for the -min*-min case
    prod + ceil_log2(n_products.max(1))
}

/// Ceiling of log2 (0 for n<=1).
pub fn ceil_log2(n: u32) -> u32 {
    if n <= 1 {
        0
    } else {
        32 - (n - 1).leading_zeros()
    }
}

/// A 3×3 (generally K×K) dot product at full precision — the behavioral
/// core of every conv IP. `data` and `coef` must both be `k*k` long.
pub fn window_dot(data: &[i64], coef: &[i64]) -> i64 {
    assert_eq!(data.len(), coef.len(), "window arity");
    data.iter().zip(coef).map(|(&d, &c)| d * c).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;

    #[test]
    fn format_bounds() {
        let q8 = Format::Q8;
        assert_eq!(q8.min(), -128);
        assert_eq!(q8.max(), 127);
        assert!(q8.contains(-128) && q8.contains(127));
        assert!(!q8.contains(128) && !q8.contains(-129));
    }

    #[test]
    fn quantize_saturates_and_rounds() {
        let q8 = Format::Q8;
        assert_eq!(q8.quantize(1000.0), 127);
        assert_eq!(q8.quantize(-1000.0), -128);
        assert_eq!(q8.quantize(2.4), 2);
        assert_eq!(q8.quantize(2.5), 3);
        assert_eq!(q8.quantize(-2.5), -3);
        let q44 = Format::new(8, 4);
        assert_eq!(q44.quantize(1.25), 20); // 1.25 * 16
        assert!((q44.to_real(20) - 1.25).abs() < 1e-12);
    }

    #[test]
    fn sat_and_wrap() {
        assert_eq!(sat(200, 8), 127);
        assert_eq!(sat(-200, 8), -128);
        assert_eq!(sat(5, 8), 5);
        assert_eq!(wrap(128, 8), -128);
        assert_eq!(wrap(-129, 8), 127);
        assert_eq!(wrap(255, 8), -1);
        assert_eq!(wrap(5, 8), 5);
    }

    #[test]
    fn requantize_truncate_is_floor_shift() {
        assert_eq!(requantize(10, 2, Round::Truncate, 8), 2);
        assert_eq!(requantize(-10, 2, Round::Truncate, 8), -3); // floor(-2.5)
        assert_eq!(requantize(1 << 20, 4, Round::Truncate, 8), 127); // saturates
    }

    #[test]
    fn requantize_nearest_even_ties() {
        // 2.5 -> 2 (even), 3.5 -> 4 (even), with shift=1
        assert_eq!(requantize(5, 1, Round::NearestEven, 8), 2);
        assert_eq!(requantize(7, 1, Round::NearestEven, 8), 4);
        assert_eq!(requantize(6, 1, Round::NearestEven, 8), 3); // exact
        assert_eq!(requantize(-5, 1, Round::NearestEven, 8), -2); // -2.5 -> -2 (even)
    }

    #[test]
    fn acc_bits_examples() {
        // 8x8 products summed over a 3x3 window: 16 + ceil(log2 9) = 20
        assert_eq!(acc_bits(8, 8, 9), 20);
        assert_eq!(ceil_log2(1), 0);
        assert_eq!(ceil_log2(2), 1);
        assert_eq!(ceil_log2(9), 4);
    }

    #[test]
    fn window_dot_matches_manual() {
        let d = [1, 2, 3, 4, 5, 6, 7, 8, 9];
        let c = [9, 8, 7, 6, 5, 4, 3, 2, 1];
        assert_eq!(window_dot(&d, &c), 165);
    }

    #[test]
    fn prop_requantize_bounds() {
        forall("requantize in range", 500, |g| {
            let acc = g.i64_in(-(1 << 30), 1 << 30);
            let shift = g.i64_in(0, 12) as u32;
            let mode = if g.bool() { Round::Truncate } else { Round::NearestEven };
            let v = requantize(acc, shift, mode, 8);
            if (-128..=127).contains(&v) {
                Ok(())
            } else {
                Err(format!("acc={acc} shift={shift} -> {v}"))
            }
        });
    }

    #[test]
    fn prop_wrap_idempotent_on_fitting() {
        forall("wrap fixpoint", 500, |g| {
            let bits = g.i64_in(2, 16) as u32;
            let v = g.signed_bits(bits);
            if wrap(v, bits) == v {
                Ok(())
            } else {
                Err(format!("v={v} bits={bits}"))
            }
        });
    }

    #[test]
    fn prop_acc_never_overflows_window() {
        forall("window acc fits acc_bits", 300, |g| {
            let k = *g.choose(&[1usize, 3, 5, 7]);
            let bits = g.i64_in(2, 12) as u32;
            let d = g.signed_vec(bits, k * k);
            let c = g.signed_vec(bits, k * k);
            let acc = window_dot(&d, &c);
            let need = acc_bits(bits, bits, (k * k) as u32);
            if Format::new(need.min(48), 0).contains(acc) {
                Ok(())
            } else {
                Err(format!("k={k} bits={bits} acc={acc} need={need}"))
            }
        });
    }
}
