//! Dual-multiply operand packing for a single DSP48E2 — the arithmetic
//! heart of the paper's `Conv_3` IP.
//!
//! A DSP48E2 has one 27×18-bit signed multiplier. Two narrow multiplies
//! `a1·b` and `a2·b` (same coefficient `b`, two different pixels — exactly
//! a convolution applied at two horizontally adjacent output positions)
//! can share it by packing the pixels into the wide 27-bit port:
//!
//! ```text
//!   P += (a1 · 2^S + a2) · b   =   (a1·b) · 2^S + (a2·b)
//! ```
//!
//! After K² accumulation steps, the low `S` bits of the 48-bit accumulator
//! hold `Σ a2·b` (two's complement) and the remaining high bits hold
//! `Σ a1·b` *provided the low lane never overflows into the high lane*.
//! The lane-split condition is
//!
//! ```text
//!   n · 2^(a_bits + b_bits - 2)  ≤  2^(S-1) − 1        (low lane fits)
//!   S + a_bits                   ≤  27                 (port fits)
//! ```
//!
//! For the paper's configuration — 8-bit operands, 3×3 kernel (n = 9) —
//! the smallest safe shift is S = 19 and 19 + 8 = 27: the packing *just*
//! fits the DSP48E2 port. Anything wider is infeasible, which is exactly
//! why the paper notes `Conv_3` is "limited up to 8-bit operands, resulting
//! in reduced precision". This module derives that limit rather than
//! hard-coding it.

use super::ceil_log2;

/// DSP48E2 port widths (UltraScale+): the pre-adder output / A:D path is
/// 27 bits, the B port 18 bits, the accumulator 48 bits.
pub const DSP_A_BITS: u32 = 27;
pub const DSP_B_BITS: u32 = 18;
pub const DSP_P_BITS: u32 = 48;

/// A feasible packing configuration.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Packing {
    /// Pixel operand width (signed bits).
    pub a_bits: u32,
    /// Coefficient width (signed bits).
    pub b_bits: u32,
    /// Number of accumulated products (K² for a K×K kernel).
    pub n_taps: u32,
    /// Lane shift S.
    pub shift: u32,
}

/// Compute the minimal feasible lane shift for packing two `a_bits`-bit
/// pixels against `b_bits`-bit coefficients accumulated over `n_taps`
/// products. Returns `None` when no shift satisfies both the lane-overflow
/// and the 27-bit-port constraints — the resource-driven planner uses this
/// to rule `Conv_3` out for wide-operand layers.
pub fn feasible(a_bits: u32, b_bits: u32, n_taps: u32) -> Option<Packing> {
    assert!(a_bits >= 2 && b_bits >= 2 && n_taps >= 1);
    // Low lane must hold sum of n products, each |p| ≤ 2^(a+b-2):
    // need S ≥ a+b-1+ceil_log2(n) (signed field of S bits holds ±2^(S-1)).
    let shift = a_bits + b_bits - 1 + ceil_log2(n_taps);
    // High lane occupies bits [S, S+a_bits+b_bits-1+log2 n); the packed A
    // operand needs S + a_bits bits and must fit the 27-bit port.
    if shift + a_bits > DSP_A_BITS {
        return None;
    }
    if b_bits > DSP_B_BITS {
        return None;
    }
    // Accumulator: high lane top bit position must fit 48.
    if shift + a_bits + b_bits - 1 + ceil_log2(n_taps) > DSP_P_BITS {
        return None;
    }
    Some(Packing { a_bits, b_bits, n_taps, shift })
}

/// Maximum operand width (a_bits == b_bits) packable for a K×K kernel.
/// For k = 3 this returns 8 — the paper's Table I limit for `Conv_3`.
pub fn max_symmetric_bits(k: u32) -> u32 {
    let n = k * k;
    let mut best = 0;
    for w in 2..=DSP_B_BITS {
        if feasible(w, w, n).is_some() {
            best = w;
        }
    }
    best
}

impl Packing {
    /// Does this configuration need the high-lane pixel clamped to
    /// `min+1`? When `S + a_bits == 27` the packed value
    /// `a1·2^S + a2` overflows the 27-bit port for `a1 = −2^(w−1)` with a
    /// negative `a2` (it exceeds −2^26 by `|a2|`). The standard INT8
    /// packing technique restricts the operand range by one code to avoid
    /// this corner — the concrete mechanism behind the paper's `Conv_3`
    /// "reduced precision" note.
    pub fn needs_high_clamp(&self) -> bool {
        self.shift + self.a_bits == DSP_A_BITS
    }

    /// Clamp a high-lane pixel per [`Packing::needs_high_clamp`].
    pub fn clamp_high(&self, a1: i64) -> i64 {
        let min = -(1i64 << (self.a_bits - 1));
        if self.needs_high_clamp() && a1 == min {
            min + 1
        } else {
            a1
        }
    }

    /// Pack two pixel operands into the wide port value. The caller must
    /// have applied [`Packing::clamp_high`] to `a1`.
    pub fn pack(&self, a1: i64, a2: i64) -> i64 {
        debug_assert!(fits_signed(a1, self.a_bits), "a1={a1}");
        debug_assert!(fits_signed(a2, self.a_bits), "a2={a2}");
        let packed = (a1 << self.shift) + a2;
        debug_assert!(
            !self.needs_high_clamp() || fits_signed(packed, DSP_A_BITS),
            "packed value {packed} overflows the 27-bit port — clamp_high not applied?"
        );
        packed
    }

    /// One packed MAC step: returns the accumulator increment.
    pub fn mac(&self, a1: i64, a2: i64, b: i64) -> i64 {
        debug_assert!(fits_signed(b, self.b_bits), "b={b}");
        self.pack(a1, a2) * b
    }

    /// Split a final accumulator into the two lane sums `(Σ a1·b, Σ a2·b)`.
    ///
    /// The low lane is the sign-extended low `shift` bits; the high lane is
    /// recovered exactly by subtracting it out (this is the "correction
    /// logic" the fabric implements around the DSP).
    pub fn split(&self, acc: i64) -> (i64, i64) {
        let low = sign_extend(acc & ((1i64 << self.shift) - 1), self.shift);
        let high = (acc - low) >> self.shift;
        (high, low)
    }
}

/// Does `v` fit a signed `bits`-bit field?
pub fn fits_signed(v: i64, bits: u32) -> bool {
    v >= -(1i64 << (bits - 1)) && v <= (1i64 << (bits - 1)) - 1
}

/// Sign-extend the low `bits` bits of `v`.
pub fn sign_extend(v: i64, bits: u32) -> i64 {
    let shift = 64 - bits;
    (v << shift) >> shift
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;
    use crate::util::rng::Rng;

    #[test]
    fn paper_limit_is_8_bits_for_3x3() {
        // The headline derivation: 3×3 packing caps at 8-bit operands.
        assert_eq!(max_symmetric_bits(3), 8);
        let p = feasible(8, 8, 9).unwrap();
        assert_eq!(p.shift, 19);
        assert_eq!(p.shift + p.a_bits, DSP_A_BITS); // exactly fills the port
        assert!(feasible(9, 9, 9).is_none());
    }

    #[test]
    fn wider_kernels_need_narrower_operands() {
        // 5×5: 25 taps -> ceil_log2 = 5 -> S = 2w-1+5; S + w ≤ 27 -> w ≤ 7
        assert_eq!(max_symmetric_bits(5), 7);
        assert!(max_symmetric_bits(7) <= 7);
        // 1×1 packing is roomy
        assert!(max_symmetric_bits(1) >= 9);
    }

    #[test]
    fn single_mac_split_exact() {
        let p = feasible(8, 8, 9).unwrap();
        for (a1, a2, b) in [(127, -128, -128), (-127, 127, 127), (0, -1, 1), (-1, 0, -1), (5, -7, 3)] {
            let acc = p.mac(a1, a2, b);
            let (h, l) = p.split(acc);
            assert_eq!((h, l), (a1 * b, a2 * b), "a1={a1} a2={a2} b={b}");
        }
    }

    #[test]
    fn high_clamp_boundary() {
        // 8-bit/3x3 sits exactly on the port boundary -> clamp required.
        let p = feasible(8, 8, 9).unwrap();
        assert!(p.needs_high_clamp());
        assert_eq!(p.clamp_high(-128), -127);
        assert_eq!(p.clamp_high(-127), -127);
        assert_eq!(p.clamp_high(127), 127);
        // Worst clamped packing fits the port.
        assert!(fits_signed(p.pack(-127, -128), DSP_A_BITS));
        assert!(fits_signed(p.pack(127, 127), DSP_A_BITS));
        // Narrower operands don't need the clamp.
        let q = feasible(6, 6, 9).unwrap();
        assert!(!q.needs_high_clamp());
        assert_eq!(q.clamp_high(-32), -32);
        assert!(fits_signed(q.pack(-32, -32), DSP_A_BITS));
    }

    #[test]
    fn accumulated_window_split_exact_worst_case() {
        // All-extreme 3×3 window: the configuration that would overflow a
        // lane one bit narrower.
        let p = feasible(8, 8, 9).unwrap();
        let a1 = p.clamp_high(-128); // boundary config clamps to -127
        let mut acc = 0i64;
        for _ in 0..9 {
            acc += p.mac(a1, -128, -128);
        }
        let (h, l) = p.split(acc);
        assert_eq!(h, 9 * a1 * (-128));
        assert_eq!(l, 9 * (-128i64) * (-128));
        let mut acc2 = 0i64;
        for _ in 0..9 {
            acc2 += p.mac(a1, 127, -128);
        }
        let (h2, l2) = p.split(acc2);
        assert_eq!(h2, 9 * a1 * (-128));
        assert_eq!(l2, 9 * 127i64 * (-128));
    }

    #[test]
    fn lane_one_bit_narrower_would_corrupt() {
        // Sanity that S=19 is genuinely minimal: with S=18 the worst-case
        // low-lane sum overflows its field.
        let bogus = Packing { a_bits: 8, b_bits: 8, n_taps: 9, shift: 18 };
        let mut acc = 0i64;
        for _ in 0..9 {
            acc += bogus.mac(1, -128, -128); // low lane sums to +147456 > 2^17-1
        }
        let (h, _l) = bogus.split(acc);
        assert_ne!(h, 9, "S=18 must corrupt the high lane in the worst case");
    }

    #[test]
    fn prop_packed_equals_two_macs() {
        forall("packed MAC == two scalar MACs", 400, |g| {
            let k = *g.choose(&[1u32, 3, 5]);
            let w = super::max_symmetric_bits(k);
            let p = feasible(w, w, k * k).expect("feasible by construction");
            let n = (k * k) as usize;
            let a1: Vec<i64> = g.signed_vec(w, n).into_iter().map(|v| p.clamp_high(v)).collect();
            let a2 = g.signed_vec(w, n);
            let b = g.signed_vec(w, n);
            let mut acc = 0i64;
            for i in 0..n {
                acc += p.mac(a1[i], a2[i], b[i]);
            }
            let (h, l) = p.split(acc);
            let want_h: i64 = (0..n).map(|i| a1[i] * b[i]).sum();
            let want_l: i64 = (0..n).map(|i| a2[i] * b[i]).sum();
            if (h, l) == (want_h, want_l) {
                Ok(())
            } else {
                Err(format!("k={k} w={w}: got ({h},{l}) want ({want_h},{want_l})"))
            }
        });
    }

    #[test]
    fn prop_sign_extend_involution() {
        forall("sign_extend fixpoint", 300, |g| {
            let bits = g.i64_in(2, 48) as u32;
            let v = g.signed_bits(bits.min(48) as u32);
            let masked = v & ((1i64 << bits) - 1);
            if sign_extend(masked, bits) == v {
                Ok(())
            } else {
                Err(format!("v={v} bits={bits}"))
            }
        });
    }

    #[test]
    fn randomized_dense_sweep_8bit() {
        // Dense deterministic sweep at the paper's exact configuration.
        let p = feasible(8, 8, 9).unwrap();
        let mut rng = Rng::new(0xC0FFEE);
        for _ in 0..2000 {
            let mut acc = 0i64;
            let mut want_h = 0i64;
            let mut want_l = 0i64;
            for _ in 0..9 {
                let (a1, a2, b) =
                    (p.clamp_high(rng.signed_bits(8)), rng.signed_bits(8), rng.signed_bits(8));
                acc += p.mac(a1, a2, b);
                want_h += a1 * b;
                want_l += a2 * b;
            }
            assert_eq!(p.split(acc), (want_h, want_l));
        }
    }
}
