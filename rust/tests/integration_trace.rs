//! End-to-end tracing integration tests: a traced step-load run under the
//! rebalancer must record a complete, contiguous six-stage span chain for
//! every completed request, a fleet event for every scale action, and a
//! Chrome trace-event export that passes the `acf trace-check` validator —
//! with retired replicas' history keeping its own labelled track.

use acf::cnn::data::Dataset;
use acf::cnn::model::{Model, Weights};
use acf::fabric::device::by_name;
use acf::planner::Policy;
use acf::serve::{
    FleetFrontier, FleetSpec, RebalanceConfig, Rebalancer, ServeConfig, Server,
};
use acf::trace::{
    chrome_trace, pid_of_group, tid_of_replica, validate_chrome_trace, EventKind, TraceEvent,
    Tracer, PID_REQUESTS, REQUEST_STAGES, TIDS_PER_REPLICA,
};
use acf::util::json::Json;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

fn corpus(n: usize, seed: u64) -> Vec<Vec<i64>> {
    Dataset::generate(n, seed, 16, 16).images.iter().map(|i| i.pix.clone()).collect()
}

/// Poll `cond` until it holds or `timeout` expires; returns whether it
/// held.
fn wait_for(timeout: Duration, mut cond: impl FnMut() -> bool) -> bool {
    let deadline = Instant::now() + timeout;
    while Instant::now() < deadline {
        if cond() {
            return true;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    cond()
}

/// Group `"request"`-process spans by request id (the tid), each chain
/// sorted by start time.
fn request_chains(events: &[TraceEvent]) -> BTreeMap<u64, Vec<TraceEvent>> {
    let mut chains: BTreeMap<u64, Vec<TraceEvent>> = BTreeMap::new();
    for e in events {
        if e.pid == PID_REQUESTS && e.kind == EventKind::Span {
            chains.entry(e.tid).or_default().push(e.clone());
        }
    }
    for spans in chains.values_mut() {
        spans.sort_by_key(|e| (e.ts_nanos, e.ts_nanos + e.dur_nanos));
    }
    chains
}

/// One request's spans must be exactly the six pipeline stages, in order,
/// contiguous (each stage starts where the previous ended — so the chain
/// cannot overlap itself) and monotone admit ≤ dispatch ≤ reply-end.
fn assert_complete_chain(tid: u64, spans: &[TraceEvent]) {
    let names: Vec<&str> = spans.iter().map(|e| e.name.as_str()).collect();
    assert_eq!(names, REQUEST_STAGES, "request {tid}: stage set/order");
    for e in spans {
        assert_eq!(e.cat, "request", "request {tid}: span '{}' category", e.name);
    }
    for pair in spans.windows(2) {
        assert_eq!(
            pair[0].ts_nanos + pair[0].dur_nanos,
            pair[1].ts_nanos,
            "request {tid}: '{}' must end exactly where '{}' begins",
            pair[0].name,
            pair[1].name
        );
    }
    let (admit, dispatch, reply) = (&spans[0], &spans[3], &spans[5]);
    assert!(admit.ts_nanos <= dispatch.ts_nanos, "request {tid}: admit after dispatch");
    assert!(
        dispatch.ts_nanos <= reply.ts_nanos + reply.dur_nanos,
        "request {tid}: dispatch after reply"
    );
}

#[test]
fn traced_step_load_yields_complete_chains_and_fleet_events() {
    // The PR 5 step-load scenario — grow under a spike, shrink in the
    // lull — run with the trace sink live: the whole story (every request
    // chain, every scale action, the retired replica's work) must come
    // back out of the ring.
    let m = Model::lenet_tiny();
    let w = Weights::random(&m, 42);
    let spec = FleetSpec::single(by_name("zcu104").unwrap(), None);
    let frontier = FleetFrontier::build(&m, &spec, 200.0, &Policy::adaptive(), 3).unwrap();
    let fp = frontier.fleet_at(&[1]);
    assert_eq!(fp.replicas(), 1);

    let model = Arc::new(m.clone());
    let weights = Arc::new(w.clone());
    let tracer = Tracer::ring(1 << 18);
    let mut cfg = ServeConfig::sized(8, 4);
    cfg.tracer = tracer.clone();
    let server = Arc::new(Server::start(
        fp.deploy_shared(Arc::clone(&model), Arc::clone(&weights)),
        &cfg,
    ));
    let rb = Rebalancer::start(
        Arc::clone(&server),
        frontier,
        &fp,
        vec![Arc::clone(&weights)],
        RebalanceConfig {
            window: Duration::from_millis(100),
            headroom: 0.25,
            cooldown: Duration::from_millis(150),
            min_replicas: 1,
        },
    );

    let images = corpus(8, 7);

    // Phase 1 — low load.
    for img in images.iter().take(4) {
        server.submit_wait(img.clone()).unwrap().wait().unwrap();
    }

    // Phase 2 — spike from closed-loop threads until the controller grows
    // the group.
    let stop = Arc::new(AtomicBool::new(false));
    let mut spikers = Vec::new();
    for t in 0..8usize {
        let server = Arc::clone(&server);
        let stop = Arc::clone(&stop);
        let images = images.clone();
        spikers.push(std::thread::spawn(move || {
            let mut sent = 0usize;
            let mut k = t;
            while !stop.load(Ordering::Relaxed) {
                let idx = k % images.len();
                k += 1;
                server.submit_wait(images[idx].clone()).unwrap().wait().unwrap();
                sent += 1;
            }
            sent
        }));
    }
    let grew = wait_for(Duration::from_secs(20), || server.live_counts()[0] > 1);
    stop.store(true, Ordering::Relaxed);
    let spike_sent: usize = spikers.into_iter().map(|h| h.join().unwrap()).sum();
    assert!(grew, "fleet never scaled up under the spike");
    assert!(spike_sent > 0);

    // Phase 3 — lull: the shrink retires a replica while tracing is live.
    let shrank = wait_for(Duration::from_secs(20), || server.live_counts()[0] == 1);
    assert!(shrank, "fleet never shrank back in the lull: {:?}", server.live_counts());

    rb.stop();
    let snap = server.shutdown();
    let events = tracer.drain();
    assert_eq!(tracer.dropped(), 0, "ring must not overflow at this scale");
    assert_eq!(snap.completed, snap.accepted, "admitted requests must all complete");
    assert_eq!(snap.failed, 0);

    // (1) Every completed request left a complete chain, and ids are
    // dense from 1 (closed-loop submit_wait never sheds an id).
    let chains = request_chains(&events);
    let ids: Vec<u64> = chains.keys().copied().collect();
    let want: Vec<u64> = (1..=snap.completed).collect();
    assert_eq!(ids, want, "one chain per completed request, ids dense from 1");
    for (tid, spans) in &chains {
        assert_complete_chain(*tid, spans);
    }

    // (2) Fleet lifecycle on the control track: one replica_add per
    // registration, a traced retirement for the shrink, and one
    // rebalance_* instant per timeline entry.
    let count = |name: &str| events.iter().filter(|e| e.name == name).count();
    assert_eq!(count("replica_add"), snap.replicas.len());
    assert!(count("replica_retire") >= 1, "shrink must trace a retirement");
    let rebalances = events.iter().filter(|e| e.name.starts_with("rebalance_")).count();
    assert!(!snap.events.is_empty(), "the controller must have acted");
    assert_eq!(rebalances, snap.events.len(), "one instant per rebalance timeline entry");

    // (3) Retired replicas' spans survive: every retired replica that
    // served images keeps its infer_batch spans on its own track.
    assert!(snap.replicas.iter().any(|r| r.retired), "the shrink retired a replica");
    for (id, r) in snap.replicas.iter().enumerate() {
        if r.retired && r.images > 0 {
            assert!(
                events.iter().any(|e| e.pid == pid_of_group(r.group)
                    && e.tid == tid_of_replica(id)
                    && e.name == "infer_batch"),
                "retired replica {id} lost its spans"
            );
        }
    }

    // (4) The export round-trips through the CI validator: serialize,
    // re-parse, validate — same path as `acf serve --trace` + trace-check.
    let mut processes = vec![(PID_REQUESTS, "requests".to_string())];
    for (g, label) in fp.group_labels().iter().enumerate() {
        processes.push((pid_of_group(g), format!("group {g}: {label}")));
    }
    let threads: Vec<(u64, u64, String)> = snap
        .replicas
        .iter()
        .enumerate()
        .map(|(id, r)| (pid_of_group(r.group), tid_of_replica(id), format!("replica {id}")))
        .collect();
    let doc = chrome_trace(&events, &processes, &threads);
    let parsed = Json::parse(&doc.dump()).unwrap();
    let chk = validate_chrome_trace(&parsed).unwrap();
    assert_eq!(chk.metadata, processes.len() + threads.len());
    assert_eq!(
        chk.request_tracks,
        chains.len() + usize::from(snap.rejected > 0),
        "one request track per chain (plus the shed track if anything shed)"
    );
    assert!(chk.spans >= chains.len() * REQUEST_STAGES.len());
}

#[test]
fn retired_replica_history_keeps_its_track_in_the_export() {
    // Deterministic victim: feed a 2-replica fleet until a chosen replica
    // has demonstrably served, retire it, keep serving on the survivor —
    // the victim's batch and per-layer spans must still come out of the
    // sink and land on its labelled track in the export.
    let m = Model::lenet_tiny();
    let w = Weights::random(&m, 5);
    let dev = by_name("zcu104").unwrap();
    let fp = FleetSpec::single(dev, Some(2)).plan().model(&m).run().unwrap();
    let model = Arc::new(m.clone());
    let weights = Arc::new(w.clone());
    let tracer = Tracer::ring(1 << 16);
    let mut cfg = ServeConfig::default();
    cfg.dispatch.max_batch = 4;
    cfg.tracer = tracer.clone();
    let server = Server::start(
        fp.deploy_shared(Arc::clone(&model), Arc::clone(&weights)),
        &cfg,
    );

    let images = corpus(8, 3);
    let victim = server.replica_ids_of_group(0)[0];
    // Throughput-weighted dispatch spreads batches, but nothing promises
    // which replica gets any particular one — feed until the victim has
    // served at least one.
    let fed = wait_for(Duration::from_secs(10), || {
        let pend: Vec<_> =
            images.iter().map(|img| server.submit_wait(img.clone()).unwrap()).collect();
        for p in pend {
            p.wait().unwrap();
        }
        server.metrics().snapshot().replicas[victim].images > 0
    });
    assert!(fed, "victim replica never served a batch");

    let report = server.retire_replica(victim).unwrap();
    assert!(report.drained);
    for img in images.iter().take(4) {
        server.submit_wait(img.clone()).unwrap().wait().unwrap();
    }
    let snap = server.shutdown();
    let events = tracer.drain();
    assert!(snap.replicas[victim].retired);

    // The victim's tid block still holds its work: the batch span on the
    // base tid, per-layer pipeline spans on the worker tids above it.
    let base = tid_of_replica(victim);
    let victim_spans: Vec<&TraceEvent> = events
        .iter()
        .filter(|e| {
            e.pid == pid_of_group(0)
                && e.tid >= base
                && e.tid < base + TIDS_PER_REPLICA
                && e.kind == EventKind::Span
        })
        .collect();
    assert!(
        victim_spans.iter().any(|e| e.name == "infer_batch" && e.cat == "replica"),
        "retired replica's batch spans must survive"
    );
    assert!(
        victim_spans.iter().any(|e| e.cat == "sim" && e.tid > base),
        "retired replica's per-layer spans must survive"
    );

    // And the export still carries a labelled track for it.
    let processes =
        vec![(PID_REQUESTS, "requests".to_string()), (pid_of_group(0), "group 0".to_string())];
    let threads: Vec<(u64, u64, String)> = snap
        .replicas
        .iter()
        .enumerate()
        .map(|(id, r)| (pid_of_group(r.group), tid_of_replica(id), format!("replica {id}")))
        .collect();
    assert_eq!(threads.len(), 2, "retired replicas stay in the registry");
    let doc = chrome_trace(&events, &processes, &threads);
    let chk = validate_chrome_trace(&doc).unwrap();
    assert_eq!(chk.metadata, processes.len() + threads.len());
    assert!(chk.spans > 0);
    assert!(chk.request_tracks > 0);
}
