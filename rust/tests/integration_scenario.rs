//! Scenario-harness integration tests: byte-identical verdict reports
//! for a fixed (scenario, seed) pair, clean whole-fleet-loss failures,
//! and the real server's fault-injection surface (replica kill without
//! drain, whole-group loss, latency degradation) under live load.

use acf::cnn::data::Dataset;
use acf::cnn::model::{model_by_name, Model, Weights};
use acf::fabric::device::by_name;
use acf::planner::Policy;
use acf::serve::{
    compose_frontier, run_scenario, FaultEventKind, FleetEntry, FleetFrontier, FleetPlan,
    FleetSpec, Scenario, ScenarioOpts, ServeConfig, Server,
};
use acf::trace::Tracer;
use std::sync::Arc;
use std::time::Duration;

fn corpus(n: usize, seed: u64) -> Vec<Vec<i64>> {
    Dataset::generate(n, seed, 16, 16).images.iter().map(|i| i.pix.clone()).collect()
}

/// Plan the fleet a scenario names, the same way the CLI does: the
/// top-level model for untenanted scenarios, otherwise the zoo of every
/// tenant's model in first-use order.
fn plan_for(sc: &Scenario) -> FleetPlan {
    let mut names: Vec<&str> = Vec::new();
    if sc.tenants.is_empty() {
        names.push(&sc.model);
    } else {
        for t in &sc.tenants {
            if !names.contains(&t.model.as_str()) {
                names.push(&t.model);
            }
        }
    }
    let models: Vec<Arc<Model>> = names
        .iter()
        .map(|n| Arc::new(model_by_name(n).unwrap_or_else(|| panic!("unknown model '{n}'"))))
        .collect();
    let spec = FleetSpec::parse(&sc.devices, &[]).unwrap();
    let frontier =
        FleetFrontier::build_zoo(models, &spec, 200.0, &Policy::adaptive(), 8).unwrap();
    compose_frontier(&frontier, None)
}

fn shipped_scenario(name: &str) -> Scenario {
    let path = format!("{}/scenarios/{name}", env!("CARGO_MANIFEST_DIR"));
    let text = std::fs::read_to_string(&path).unwrap_or_else(|e| panic!("{path}: {e}"));
    Scenario::from_str(&text).unwrap_or_else(|e| panic!("{path}: {e}"))
}

#[test]
fn replica_death_verdict_is_byte_identical_across_runs() {
    // The acceptance contract: the shipped replica_death scenario at
    // seed 7, run twice against the same plan, serializes to identical
    // bytes — and passes its recovery-time and zero-drop assertions.
    let sc = shipped_scenario("replica_death.json");
    let fp = plan_for(&sc);
    let opts = ScenarioOpts { seed: 7, quick: false, tracer: Tracer::off() };
    let a = run_scenario(&sc, &fp, &opts).unwrap();
    let b = run_scenario(&sc, &fp, &opts).unwrap();
    assert_eq!(a.to_json().dump(), b.to_json().dump(), "verdict bytes must be reproducible");
    assert!(a.passed, "shipped replica_death scenario must pass: {}", a.to_json().dump());
    assert_eq!(a.drops, 0, "no admitted request may be dropped by a single replica death");
    assert!(!a.fleet_lost);
    // The fault recovered, and the phase carries an explicit passing
    // recovery-time check.
    assert_eq!(a.faults.len(), 1);
    assert!(a.faults[0].recovered, "survivor must absorb the load");
    let recovery_checks: Vec<_> = a
        .phases
        .iter()
        .flat_map(|p| &p.checks)
        .filter(|c| c.name == "recovery_ms_max")
        .collect();
    assert_eq!(recovery_checks.len(), 1);
    assert!(recovery_checks[0].passed);
}

#[test]
fn every_shipped_scenario_parses_and_plans() {
    // scenario-check's precondition: the six shipped files must parse
    // and their fleets must plan. Quick mode must keep verdicts green.
    for name in [
        "diurnal.json",
        "flash_crowd.json",
        "replica_death.json",
        "group_loss.json",
        "latency_degrade.json",
        "multi_tenant.json",
    ] {
        let sc = shipped_scenario(name);
        let fp = plan_for(&sc);
        let opts = ScenarioOpts { seed: 7, quick: true, tracer: Tracer::off() };
        let report = run_scenario(&sc, &fp, &opts).unwrap_or_else(|e| panic!("{name}: {e}"));
        assert!(report.passed, "{name} must pass in quick mode: {}", report.to_json().dump());
    }
}

#[test]
fn multi_tenant_verdict_is_byte_identical_and_sheds_under_overload() {
    // The two-model, two-tenant shipped scenario: reproducible verdict
    // bytes, a per-tenant breakdown in every phase, and admission-side
    // shedding in the 2x overload phase.
    let sc = shipped_scenario("multi_tenant.json");
    assert_eq!(sc.tenants.len(), 2);
    let fp = plan_for(&sc);
    assert_eq!(fp.models.len(), 2, "two-model zoo plan");
    let opts = ScenarioOpts { seed: 7, quick: false, tracer: Tracer::off() };
    let a = run_scenario(&sc, &fp, &opts).unwrap();
    let b = run_scenario(&sc, &fp, &opts).unwrap();
    assert_eq!(a.to_json().dump(), b.to_json().dump(), "verdict bytes must be reproducible");
    assert!(a.passed, "shipped multi_tenant scenario must pass: {}", a.to_json().dump());
    for p in &a.phases {
        assert_eq!(p.tenants.len(), 2, "phase '{}' carries the tenant breakdown", p.name);
        assert_eq!(p.tenants[0].name, "acme");
        assert_eq!(p.tenants[1].name, "bitworks");
    }
    let rush = a.phases.iter().find(|p| p.name == "rush").unwrap();
    let shed: u64 = rush.tenants.iter().map(|t| t.shed).sum();
    assert!(shed > 0, "2x overload must shed at admission: {}", a.to_json().dump());
    assert!(a.to_json().dump().contains("\"tenants\""));
}

#[test]
fn whole_fleet_loss_is_a_clean_fail_not_an_error() {
    // Killing the fleet's last replica mid-phase: the engine must return
    // a FAILED verdict (dropped admissions, fleet_lost) — never an Err
    // and never a panic.
    let src = r#"{
        "name": "total_loss",
        "devices": "zcu104:1",
        "recovery_tail": 16,
        "phases": [
            {
                "name": "doomed",
                "requests": 200,
                "load": { "profile": "constant", "rate_x": 0.5 },
                "faults": [ { "kind": "group_loss", "group": 0, "at_frac": 0.3 } ],
                "asserts": { "zero_drops": true }
            }
        ]
    }"#;
    let sc = Scenario::from_str(src).unwrap();
    let fp = plan_for(&sc);
    let report =
        run_scenario(&sc, &fp, &ScenarioOpts { seed: 7, quick: false, tracer: Tracer::off() })
            .unwrap();
    assert!(!report.passed, "a dead fleet cannot pass");
    assert!(report.fleet_lost);
    assert!(report.drops > 0, "queued admissions die with the fleet");
    let zero_drop_checks: Vec<_> = report.phases[0]
        .checks
        .iter()
        .filter(|c| c.name == "zero_drops")
        .collect();
    assert_eq!(zero_drop_checks.len(), 1);
    assert!(!zero_drop_checks[0].passed, "the drop book must indict the fleet loss");
}

fn two_replica_server(cfg: &ServeConfig) -> (Server, Model, Weights) {
    let m = Model::lenet_tiny();
    let w = Weights::random(&m, 42);
    let dev = by_name("zcu104").unwrap();
    let fp = FleetSpec::single(dev, Some(2)).plan().model(&m).run().unwrap();
    let server = Server::start(fp.deploy(m.clone(), w.clone()), cfg);
    (server, m, w)
}

#[test]
fn killed_replica_never_drops_admitted_requests() {
    // Live server: admit a wave, kill one of two replicas without drain
    // mid-flight, admit another wave. Every accepted request completes
    // bit-exactly; the kill shows up on the fault timeline.
    let (server, model, weights) = two_replica_server(&ServeConfig::default());
    let images = corpus(8, 31);
    let mut pendings = Vec::new();
    for img in &images {
        pendings.push((img.clone(), server.submit_wait(img.clone()).unwrap()));
    }
    let victim = server.replica_ids_of_group(0)[0];
    server.kill_replica(victim).unwrap();
    assert_eq!(server.live_counts(), vec![1], "one survivor in rotation");
    for img in &images {
        pendings.push((img.clone(), server.submit_wait(img.clone()).unwrap()));
    }
    for (img, p) in pendings {
        assert_eq!(p.wait().unwrap(), acf::cnn::infer::infer(&model, &weights, &img));
    }
    let snap = server.shutdown();
    assert_eq!(snap.failed, 0);
    assert_eq!(snap.completed, snap.accepted);
    assert!(
        snap.faults.iter().any(|f| f.kind == FaultEventKind::ReplicaDeath),
        "kill must land on the fault timeline: {:?}",
        snap.faults
    );
    assert!(!snap.faults.iter().any(|f| f.kind == FaultEventKind::FleetLost));
}

#[test]
fn group_loss_reroutes_to_the_surviving_group() {
    // Heterogeneous fleet; kill the whole second group (its only
    // replica). Traffic reroutes to group 0, the timeline records both
    // the group_loss injection and the resulting group-lost state, and
    // nothing admitted is dropped.
    let m = Model::lenet_tiny();
    let w = Weights::random(&m, 42);
    let spec = FleetSpec {
        entries: vec![
            FleetEntry { device: by_name("zcu104").unwrap(), count: Some(1) },
            FleetEntry { device: by_name("zu5ev").unwrap(), count: Some(1) },
        ],
    };
    let fp = spec.plan().model(&m).max_replicas(2).run().unwrap();
    let server = Server::start(fp.deploy(m.clone(), w.clone()), &ServeConfig::default());
    let images = corpus(6, 17);
    let mut pendings: Vec<_> =
        images.iter().map(|img| server.submit_wait(img.clone()).unwrap()).collect();
    let killed = server.kill_group(1).unwrap();
    assert_eq!(killed, 1);
    assert_eq!(server.live_counts(), vec![1, 0]);
    // The fleet still serves — on group 0 alone.
    for img in &images {
        pendings.push(server.submit_wait(img.clone()).unwrap());
    }
    for (i, p) in pendings.into_iter().enumerate() {
        let logits = p.wait().unwrap();
        assert_eq!(logits, acf::cnn::infer::infer(&m, &w, &images[i % images.len()]));
    }
    let snap = server.shutdown();
    assert_eq!(snap.failed, 0);
    assert_eq!(snap.completed, snap.accepted);
    let kinds: Vec<_> = snap.faults.iter().map(|f| f.kind).collect();
    assert!(kinds.contains(&FaultEventKind::GroupLoss), "injection event: {kinds:?}");
    assert!(kinds.contains(&FaultEventKind::GroupLost), "resulting state event: {kinds:?}");
    assert!(!kinds.contains(&FaultEventKind::FleetLost));
}

#[test]
fn latency_injection_slows_batches_then_lifts() {
    // A 50ms-per-batch shim on the only replica must dominate the serve
    // time of sequential waits, and clearing it must restore speed. Both
    // transitions land on the fault timeline.
    let m = Model::lenet_tiny();
    let w = Weights::random(&m, 42);
    let dev = by_name("zcu104").unwrap();
    let fp = FleetSpec::single(dev, Some(1)).plan().model(&m).run().unwrap();
    let server = Server::start(fp.deploy(m.clone(), w.clone()), &ServeConfig::default());
    let images = corpus(4, 23);
    let replica = server.replica_ids_of_group(0)[0];
    let wave = |server: &Server| {
        let t0 = std::time::Instant::now();
        for img in &images {
            let p = server.submit_wait(img.clone()).unwrap();
            assert_eq!(p.wait().unwrap(), acf::cnn::infer::infer(&m, &w, img));
        }
        t0.elapsed()
    };
    server.inject_latency(replica, Duration::from_millis(50)).unwrap();
    let degraded = wave(&server);
    server.clear_latency(replica);
    let healthy = wave(&server);
    // 4 sequential waits x 50ms shim: the degraded wave carries at least
    // 200ms of injected delay; the healthy wave carries none.
    assert!(
        degraded >= Duration::from_millis(200),
        "shim must be applied per batch: {degraded:?}"
    );
    assert!(degraded > healthy, "degraded {degraded:?} vs healthy {healthy:?}");
    let snap = server.shutdown();
    assert_eq!(snap.failed, 0);
    let kinds: Vec<_> = snap.faults.iter().map(|f| f.kind).collect();
    assert!(kinds.contains(&FaultEventKind::LatencyDegrade), "{kinds:?}");
    assert!(kinds.contains(&FaultEventKind::LatencyRestore), "{kinds:?}");
}
