//! System-level integration and property tests that need no AOT
//! artifacts: planner invariants across the whole device catalog,
//! coordinator-vs-reference equivalence across model variants, netlist
//! stress runs, and failure injection on the config surfaces.

use acf::cnn::data::Dataset;
use acf::cnn::model::{Layer, Model, Weights};
use acf::coordinator::Deployment;
use acf::fabric::device::{by_name, catalog};
use acf::ips::engine::{self, EngineKind, EngineParams};
use acf::ips::{self, ConvKind, ConvParams};
use acf::planner::{baselines, plan, profile, Policy};
use acf::util::json::Json;
use acf::util::prop::forall;
use acf::util::rng::Rng;

#[test]
fn planner_invariants_catalog_x_models_x_policies() {
    // For every device × model × policy: a returned plan fits the device,
    // names a real bottleneck, and its throughput is consistent with its
    // own cycle model.
    let models = [Model::lenet_tiny(), Model::lenet_wide(2)];
    for model in &models {
        for dev in catalog() {
            for pol in baselines::all() {
                let Ok(p) = plan(model, &dev, 200.0, &pol) else { continue };
                assert!(p.total.fits(&dev), "{} {} {}", model.name, dev.name, pol.name);
                let perf = acf::sim::estimate(model, &p);
                assert!(
                    (perf.throughput_img_s - p.images_per_sec).abs() / p.images_per_sec < 1e-9
                );
                assert!(p.engines.iter().all(|ep| ep.instances >= 1));
                // Every non-conv layer type is planned too: the registry
                // leaves nothing resource-free.
                for (li, layer) in model.layers.iter().enumerate() {
                    let kinds: Vec<EngineKind> = p
                        .engines
                        .iter()
                        .filter(|ep| ep.layer == li)
                        .map(|ep| ep.kind)
                        .collect();
                    match layer {
                        Layer::Conv { relu, .. } => {
                            assert!(kinds.iter().any(|k| k.conv_kind().is_some()));
                            assert_eq!(*relu, kinds.contains(&EngineKind::Relu));
                        }
                        Layer::MaxPool => assert_eq!(kinds, vec![EngineKind::MaxPool]),
                        Layer::Fc { relu, .. } => {
                            assert!(kinds.contains(&EngineKind::Fc));
                            assert_eq!(*relu, kinds.contains(&EngineKind::Relu));
                        }
                    }
                }
                // Bottleneck must be one of the planned layers.
                assert!(p.engines.iter().any(|ep| ep.layer == p.bottleneck));
            }
        }
    }
}

#[test]
fn prop_engine_registry_roundtrips_generate_synth_profile() {
    // Every EngineKind must generate a checkable netlist, synthesize to
    // nonzero utilization, and profile (synthesis + STA) on the paper's
    // board — across random operand widths and shapes.
    let dev = by_name("zcu104").unwrap();
    forall("engine registry generate→synth→profile", 16, |g| {
        let bits = g.usize_in(4, 8) as u32;
        let fanin = g.usize_in(8, 96) as u32;
        let window = g.usize_in(2, 8) as u32;
        let p = ConvParams {
            k: 3,
            data_bits: bits,
            coef_bits: bits,
            out_bits: bits,
            shift: bits - 1,
            round: acf::fixed::Round::Truncate,
        };
        let cands: Vec<(EngineKind, EngineParams)> = ConvKind::ALL
            .iter()
            .map(|&ck| (EngineKind::Conv(ck), EngineParams::conv(p)))
            .chain([
                (EngineKind::Fc, EngineParams::fc(p, fanin)),
                (EngineKind::MaxPool, EngineParams::pool(bits, window)),
                (EngineKind::Relu, EngineParams::relu(bits)),
            ])
            .collect();
        for (kind, ep) in cands {
            let ip = engine::generate(kind, &ep)
                .map_err(|e| format!("{} bits={bits}: {e}", kind.name()))?;
            ip.netlist.check().map_err(|e| format!("{}: {e}", kind.name()))?;
            if ip.rate <= 0.0 {
                return Err(format!("{}: nonpositive rate {}", kind.name(), ip.rate));
            }
            let u = acf::synth::synthesize(&ip.netlist);
            if u.luts + u.dsps == 0 {
                return Err(format!("{}: zero utilization", kind.name()));
            }
            let prof = profile(kind, &ep, 200.0, &dev)
                .map_err(|e| format!("{} profile: {e}", kind.name()))?;
            if prof.util != u || prof.wns_ns < 0.0 {
                return Err(format!("{}: profile disagrees with synth", kind.name()));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_planner_monotone_in_clock() {
    // Higher clock can only raise modeled throughput (same assignment
    // space; WNS check can only *remove* options, so allow equal too when
    // a kind drops out — throughput in img/s still uses the higher clock).
    let m = Model::lenet_tiny();
    let dev = by_name("zu3eg").unwrap();
    let p200 = plan(&m, &dev, 200.0, &Policy::adaptive()).unwrap();
    let p100 = plan(&m, &dev, 100.0, &Policy::adaptive()).unwrap();
    assert!(p200.images_per_sec >= p100.images_per_sec);
}

#[test]
fn coordinator_matches_reference_across_models_and_seeds() {
    for (model, seed) in [(Model::lenet_tiny(), 1u64), (Model::lenet_wide(2), 2)] {
        let w = Weights::random(&model, seed);
        let dev = by_name("zcu104").unwrap();
        let dep = Deployment::new(model.clone(), w.clone(), &dev, 200.0, &Policy::adaptive()).unwrap();
        let ds = Dataset::generate(6, seed, model.in_h, model.in_w);
        let images: Vec<Vec<i64>> = ds.images.iter().map(|i| i.pix.clone()).collect();
        let got = dep.infer_batch(&images).unwrap();
        for (img, logits) in images.iter().zip(&got) {
            assert_eq!(logits, &acf::cnn::infer::infer(&model, &w, img), "{}", model.name);
        }
    }
}

#[test]
fn coordinator_identical_results_under_any_policy() {
    // IP choice must never change numerics — the core safety property of
    // adaptation (guaranteed by the symmetric-range ingress contract).
    let model = Model::lenet_tiny();
    let w = Weights::random(&model, 3);
    let dev = by_name("zcu104").unwrap();
    let ds = Dataset::generate(5, 9, 16, 16);
    let images: Vec<Vec<i64>> = ds.images.iter().map(|i| i.pix.clone()).collect();
    let mut outputs: Vec<Vec<Vec<i64>>> = Vec::new();
    for pol in baselines::all() {
        let dep = Deployment::new(model.clone(), w.clone(), &dev, 200.0, &pol).unwrap();
        outputs.push(dep.infer_batch(&images).unwrap());
    }
    for o in &outputs[1..] {
        assert_eq!(o, &outputs[0], "policies must agree bit-exactly");
    }
}

#[test]
fn netlist_stress_long_streams() {
    // 60 consecutive passes per IP (stale-state hazards, pass boundaries).
    let p = ConvParams::paper_8bit();
    for kind in ConvKind::ALL {
        let ip = ips::generate(kind, &p).unwrap();
        let n = ips::verify::check_equivalence(&ip, 0x57E55 ^ kind as u64, 60);
        assert!(n >= 60);
    }
}

#[test]
fn prop_fc_engine_matches_reference_fanins() {
    forall("fc engine == fc_ref across fan-ins", 12, |g| {
        let n = g.usize_in(2, 24) as u32;
        let p = ConvParams::paper_8bit();
        let ip = ips::fc::generate(&p, n).map_err(|e| e.to_string())?;
        let mut rng = Rng::new(n as u64 * 31 + 7);
        let xs: Vec<Vec<i64>> =
            (0..3).map(|_| (0..n).map(|_| rng.signed_bits(8)).collect()).collect();
        let ws: Vec<Vec<i64>> =
            (0..3).map(|_| (0..n).map(|_| rng.signed_bits(8)).collect()).collect();
        // Reuse the module's own test driver logic via a minimal run.
        let want: Vec<i64> = (0..3).map(|i| ips::fc::fc_ref(&p, &xs[i], &ws[i])).collect();
        let got = run_fc(&ip, &xs, &ws);
        if got == want {
            Ok(())
        } else {
            Err(format!("n={n}: {got:?} != {want:?}"))
        }
    });
}

fn run_fc(ip: &ips::fc::FcIp, xs: &[Vec<i64>], ws: &[Vec<i64>]) -> Vec<i64> {
    use acf::netlist::sim::Sim;
    let p = &ip.params;
    let n = ip.n as usize;
    let mut sim = Sim::new(&ip.netlist).unwrap();
    sim.set_input("rst", 1);
    sim.set_input("en", 1);
    sim.set_input("x", 0);
    sim.set_input("coef", 0);
    sim.settle();
    sim.tick();
    sim.set_input("rst", 0);
    let mask = (1u64 << p.data_bits) - 1;
    let total = xs.len() * n + ip.out_latency as usize + 2;
    let mut out = Vec::new();
    for cycle in 0..total {
        let phase = cycle % n;
        let neuron = (cycle / n).min(xs.len() - 1);
        sim.set_input("x", (xs[neuron][phase] as u64) & mask);
        sim.set_input("coef", (ws[neuron][phase] as u64) & mask);
        sim.settle();
        if sim.output_unsigned("valid") == 1 {
            out.push(sim.output_signed("out0"));
            if out.len() == xs.len() {
                break;
            }
        }
        sim.tick();
    }
    out
}

#[test]
fn failure_injection_config_surfaces() {
    // Malformed model JSON.
    for bad in [
        r#"{"name":"x"}"#,                                   // missing fields
        r#"{"name":"x","in_h":16,"in_w":16,"in_ch":1,"layers":[{"type":"warp"}]}"#,
        r#"not json at all"#,
    ] {
        let parsed = Json::parse(bad).and_then(|j| {
            Model::from_json(&j).map_err(|e| e)
        });
        assert!(parsed.is_err(), "must reject: {bad}");
    }
    // Geometrically invalid model must fail at plan time.
    let mut m = Model::lenet_tiny();
    m.in_h = 3;
    let dev = by_name("zcu104").unwrap();
    assert!(plan(&m, &dev, 200.0, &Policy::adaptive()).is_err());
    // Absurd clock: nothing meets timing -> infeasible, not panic.
    let m2 = Model::lenet_tiny();
    assert!(plan(&m2, &dev, 5000.0, &Policy::adaptive()).is_err());
    // Device too small for even one instance set.
    let mut tiny_dev = by_name("edge-nodsp").unwrap();
    tiny_dev.luts = 50;
    tiny_dev.clbs = 6;
    tiny_dev.dsps = 0;
    assert!(plan(&m2, &tiny_dev, 200.0, &Policy::adaptive()).is_err());
}

#[test]
fn deployment_rejects_malformed_batches() {
    let model = Model::lenet_tiny();
    let w = Weights::random(&model, 1);
    let dev = by_name("zcu104").unwrap();
    let dep = Deployment::new(model, w, &dev, 200.0, &Policy::adaptive()).unwrap();
    // Wrong size.
    assert!(dep.infer_batch(&[vec![0i64; 10]]).is_err());
    // Asymmetric pixel (-128) — the Conv_3 packing hazard.
    let mut img = vec![0i64; 256];
    img[200] = -128;
    assert!(dep.infer_batch(&[img]).is_err());
    // Out-of-range pixel.
    let mut img2 = vec![0i64; 256];
    img2[0] = 300;
    assert!(dep.infer_batch(&[img2]).is_err());
}

#[test]
fn power_tracks_measured_activity() {
    // Toggle-driven dynamic power: a busy stimulus must draw more than an
    // idle one through the measured-activity path.
    let p = ConvParams::paper_8bit();
    let ip = ips::generate(ConvKind::Conv2, &p).unwrap();
    let dev = by_name("zcu104").unwrap();
    let u = acf::synth::synthesize(&ip.netlist);
    let busy = acf::power::estimate(&u, &dev, 200.0, Some(0.4)).total_w();
    let idle = acf::power::estimate(&u, &dev, 200.0, Some(0.01)).total_w();
    assert!(busy > idle);
    assert!(idle >= dev.static_w);
}

#[test]
fn sta_monotone_under_derate_catalogwide() {
    let p = ConvParams::paper_8bit();
    let ip = ips::generate(ConvKind::Conv3, &p).unwrap();
    let mut last = f64::INFINITY;
    for derate in [0.9, 1.0, 1.12, 1.25] {
        let t = acf::sta::analyze(&ip.netlist, 200.0, derate).unwrap();
        assert!(t.wns_ns < last, "derate {derate}");
        last = t.wns_ns;
    }
}
