//! Serving-tier integration tests: concurrent callers on one persistent
//! pipeline, fleet planning invariants, end-to-end bit-exactness of the
//! scheduled path, admission control under saturation, and drain-on-
//! shutdown semantics.

use acf::cnn::data::Dataset;
use acf::cnn::model::{Model, Weights};
use acf::coordinator::Deployment;
use acf::fabric::device::by_name;
use acf::planner::Policy;
use acf::serve::{
    open_loop, plan_fixed_fleet, plan_fleet, ServeConfig, ServeError, Server,
    DEFAULT_MAX_REPLICAS,
};
use std::sync::Arc;

fn corpus(n: usize, seed: u64) -> Vec<Vec<i64>> {
    Dataset::generate(n, seed, 16, 16).images.iter().map(|i| i.pix.clone()).collect()
}

fn deploy_one() -> Deployment {
    let m = Model::lenet_tiny();
    let w = Weights::random(&m, 42);
    let dev = by_name("zcu104").unwrap();
    Deployment::new(m, w, &dev, 200.0, &Policy::adaptive()).unwrap()
}

fn fleet(replicas: usize, cfg: &ServeConfig) -> (Server, Model, Weights) {
    let m = Model::lenet_tiny();
    let w = Weights::random(&m, 42);
    let dev = by_name("zcu104").unwrap();
    let fp = plan_fixed_fleet(&m, &dev, 200.0, &Policy::adaptive(), replicas, None).unwrap();
    let server = Server::start(fp.deploy(m.clone(), w.clone()), cfg);
    (server, m, w)
}

#[test]
fn concurrent_infer_batch_is_ordered_and_exact() {
    // Many threads hammer ONE deployment's persistent pipeline; each must
    // get its own batch back in order, bit-exact, and the shared metrics
    // must account for every image exactly once.
    let dep = Arc::new(deploy_one());
    let images = corpus(10, 3);
    let want: Vec<Vec<i64>> = images
        .iter()
        .map(|img| acf::cnn::infer::infer(&dep.model, &dep.weights, img))
        .collect();
    let threads = 8;
    let rounds = 3;
    let mut handles = Vec::new();
    for t in 0..threads {
        let dep = Arc::clone(&dep);
        let images = images.clone();
        let want = want.clone();
        handles.push(std::thread::spawn(move || {
            for r in 0..rounds {
                let mut batch = images.clone();
                let mut expect = want.clone();
                batch.rotate_left((t + r) % batch.len());
                expect.rotate_left((t + r) % expect.len());
                assert_eq!(dep.infer_batch(&batch).unwrap(), expect);
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let snap = dep.metrics.snapshot();
    assert_eq!(snap.images, (threads * rounds * images.len()) as u64);
    assert_eq!(snap.batches, (threads * rounds) as u64);
    // Every layer worker did real work.
    assert!(snap.layer_secs.iter().all(|&s| s > 0.0));
}

#[test]
fn fleet_planner_replicates_the_default_device() {
    let m = Model::lenet_tiny();
    let dev = by_name("zcu104").unwrap();
    let fp =
        plan_fleet(&m, &dev, 200.0, &Policy::adaptive(), None, DEFAULT_MAX_REPLICAS).unwrap();
    assert!(fp.replicas >= 2, "zcu104 must carry at least two lenet-tiny replicas");
    assert!(fp.total.fits(&dev));
    assert!(
        (fp.fleet_img_s - fp.replicas as f64 * fp.per_replica.images_per_sec).abs() < 1e-6,
        "fleet throughput is the replica sum"
    );
}

#[test]
fn served_logits_bit_identical_to_infer_batch() {
    let (server, model, weights) = fleet(2, &ServeConfig::default());
    let images = corpus(24, 9);
    let pendings: Vec<_> =
        images.iter().map(|img| server.submit_wait(img.clone()).unwrap()).collect();
    let served: Vec<Vec<i64>> =
        pendings.into_iter().map(|p| p.wait().unwrap()).collect();
    // Same images through the one-shot path on a replica, and through the
    // plain behavioral reference: all three must agree bit for bit.
    let one_shot = server.replicas()[0].infer_batch(&images).unwrap();
    for ((img, s), b) in images.iter().zip(&served).zip(&one_shot) {
        let reference = acf::cnn::infer::infer(&model, &weights, img);
        assert_eq!(s, &reference);
        assert_eq!(b, &reference);
    }
    let snap = server.shutdown();
    // Only the scheduled path counts in fleet metrics; the one-shot
    // comparison batch went straight to the replica's own pipeline.
    assert_eq!(snap.completed, 24);
    assert_eq!(snap.failed, 0);
    assert!(snap.p50_ms <= snap.p95_ms && snap.p95_ms <= snap.p99_ms);
}

#[test]
fn saturated_queue_sheds_with_overloaded() {
    // A deliberately tiny queue and single replica: a tight submission
    // loop must hit admission control, and every *accepted* request must
    // still complete correctly.
    let cfg = ServeConfig { queue_depth: 2, max_batch: 1 };
    let (server, model, weights) = fleet(1, &cfg);
    let images = corpus(4, 5);
    let mut accepted = Vec::new();
    let mut overloaded = 0usize;
    let mut i = 0usize;
    while overloaded == 0 && i < 10_000 {
        match server.submit(images[i % images.len()].clone()) {
            Ok(p) => accepted.push((i % images.len(), p)),
            Err(ServeError::Overloaded { queue_depth }) => {
                assert_eq!(queue_depth, 2);
                overloaded += 1;
            }
            Err(e) => panic!("unexpected error: {e}"),
        }
        i += 1;
    }
    assert!(overloaded > 0, "tight loop never tripped admission control");
    for (idx, p) in accepted {
        let logits = p.wait().unwrap();
        assert_eq!(logits, acf::cnn::infer::infer(&model, &weights, &images[idx]));
    }
    let snap = server.shutdown();
    assert_eq!(snap.rejected as usize, overloaded);
    assert_eq!(snap.completed, snap.accepted);
}

#[test]
fn bad_requests_rejected_at_admission() {
    let (server, _, _) = fleet(1, &ServeConfig::default());
    assert!(matches!(
        server.submit(vec![0i64; 5]),
        Err(ServeError::BadRequest(acf::coordinator::DeployError::BadImage { .. }))
    ));
    let mut img = vec![0i64; 256];
    img[0] = -128;
    assert!(matches!(
        server.submit(img),
        Err(ServeError::BadRequest(acf::coordinator::DeployError::AsymmetricInput(-128)))
    ));
    let snap = server.shutdown();
    assert_eq!(snap.accepted, 0);
}

#[test]
fn shutdown_drains_accepted_requests() {
    let (server, model, weights) = fleet(2, &ServeConfig::default());
    let images = corpus(12, 13);
    let pendings: Vec<_> =
        images.iter().map(|img| server.submit_wait(img.clone()).unwrap()).collect();
    // Shut down immediately: everything admitted must still be answered.
    let snap = server.shutdown();
    assert_eq!(snap.completed, 12);
    for (img, p) in images.iter().zip(pendings) {
        assert_eq!(p.wait().unwrap(), acf::cnn::infer::infer(&model, &weights, img));
    }
    assert!(snap.replicas.iter().map(|r| r.images).sum::<u64>() == 12);
}

#[test]
fn open_loop_outcomes_are_complete_and_exact() {
    let (server, model, weights) = fleet(2, &ServeConfig::default());
    let images = corpus(16, 21);
    let outcomes = open_loop(&server, &images, 120, 5_000.0, 77);
    assert_eq!(outcomes.len(), 120);
    let mut served = 0usize;
    for o in &outcomes {
        match &o.result {
            Ok(logits) => {
                served += 1;
                assert_eq!(
                    logits,
                    &acf::cnn::infer::infer(&model, &weights, &images[o.image_idx])
                );
            }
            Err(ServeError::Overloaded { .. }) => {}
            Err(e) => panic!("unexpected serve error: {e}"),
        }
    }
    let snap = server.shutdown();
    assert_eq!(snap.completed as usize, served);
    assert_eq!((snap.accepted + snap.rejected) as usize, outcomes.len());
    if served > 0 {
        assert!(snap.sustained_img_s > 0.0);
        assert!(snap.p99_ms > 0.0);
    }
}
