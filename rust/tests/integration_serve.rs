//! Serving-tier integration tests: concurrent callers on one persistent
//! pipeline, fleet planning invariants (single-device and heterogeneous),
//! end-to-end bit-exactness of the scheduled path across device groups,
//! admission control under saturation, weighted-fair queueing across
//! tenants, coefficient-BRAM honesty under sharding, and
//! drain-on-shutdown semantics.

use acf::cnn::data::Dataset;
use acf::cnn::model::{Model, Weights};
use acf::coordinator::Deployment;
use acf::fabric::device::{by_name, load_catalog};
use acf::planner::Policy;
use acf::serve::{
    open_loop, FleetEntry, FleetSpec, ServeConfig, ServeError, Server, TenantSpec,
    DEFAULT_MAX_REPLICAS,
};
use std::sync::Arc;
use std::time::Duration;

fn corpus(n: usize, seed: u64) -> Vec<Vec<i64>> {
    Dataset::generate(n, seed, 16, 16).images.iter().map(|i| i.pix.clone()).collect()
}

fn deploy_one() -> Deployment {
    let m = Model::lenet_tiny();
    let w = Weights::random(&m, 42);
    let dev = by_name("zcu104").unwrap();
    Deployment::new(m, w, &dev, 200.0, &Policy::adaptive()).unwrap()
}

fn fleet(replicas: usize, cfg: &ServeConfig) -> (Server, Model, Weights) {
    let m = Model::lenet_tiny();
    let w = Weights::random(&m, 42);
    let dev = by_name("zcu104").unwrap();
    let fp = FleetSpec::single(dev, Some(replicas)).plan().model(&m).run().unwrap();
    let server = Server::start(fp.deploy(m.clone(), w.clone()), cfg);
    (server, m, w)
}

/// A single-replica fleet shared by two tenants on the same model with a
/// 3:1 quota split over an 8-deep queue — per-tenant admission caps of 6
/// and 2 slots respectively.
fn two_tenant_fleet() -> (Server, Model, Weights) {
    let mut cfg = ServeConfig::sized(8, 1);
    cfg.tenants.tenants =
        vec![TenantSpec::new("gold", "", 3.0), TenantSpec::new("bronze", "", 1.0)];
    let m = Model::lenet_tiny();
    let w = Weights::random(&m, 42);
    let dev = by_name("zcu104").unwrap();
    let fp = FleetSpec::single(dev, Some(1)).plan().model(&m).run().unwrap();
    let server = Server::start(fp.deploy(m.clone(), w.clone()), &cfg);
    (server, m, w)
}

#[test]
fn concurrent_infer_batch_is_ordered_and_exact() {
    // Many threads hammer ONE deployment's persistent pipeline; each must
    // get its own batch back in order, bit-exact, and the shared metrics
    // must account for every image exactly once.
    let dep = Arc::new(deploy_one());
    let images = corpus(10, 3);
    let want: Vec<Vec<i64>> = images
        .iter()
        .map(|img| acf::cnn::infer::infer(&dep.model, &dep.weights, img))
        .collect();
    let threads = 8;
    let rounds = 3;
    let mut handles = Vec::new();
    for t in 0..threads {
        let dep = Arc::clone(&dep);
        let images = images.clone();
        let want = want.clone();
        handles.push(std::thread::spawn(move || {
            for r in 0..rounds {
                let mut batch = images.clone();
                let mut expect = want.clone();
                batch.rotate_left((t + r) % batch.len());
                expect.rotate_left((t + r) % expect.len());
                assert_eq!(dep.infer_batch(&batch).unwrap(), expect);
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let snap = dep.metrics.snapshot();
    assert_eq!(snap.images, (threads * rounds * images.len()) as u64);
    assert_eq!(snap.batches, (threads * rounds) as u64);
    // Every layer worker did real work.
    assert!(snap.layer_secs.iter().all(|&s| s > 0.0));
}

#[test]
fn fleet_planner_replicates_the_default_device() {
    let m = Model::lenet_tiny();
    let dev = by_name("zcu104").unwrap();
    let fp = FleetSpec::single(dev.clone(), None)
        .plan()
        .model(&m)
        .max_replicas(DEFAULT_MAX_REPLICAS)
        .run()
        .unwrap();
    assert!(fp.replicas() >= 2, "zcu104 must carry at least two lenet-tiny replicas");
    assert_eq!(fp.groups.len(), 1);
    assert!(fp.groups[0].total.fits(&dev));
    assert!(
        (fp.fleet_img_s
            - fp.replicas() as f64 * fp.groups[0].per_replica.images_per_sec)
            .abs()
            < 1e-6,
        "fleet throughput is the replica sum"
    );
}

#[test]
fn heterogeneous_mix_beats_best_single_device_fleet() {
    // The pinned catalog: the paper's board plus a smaller sibling. The
    // mix's modeled throughput must beat the best fleet either part can
    // field alone — each part contributes its own replica group.
    let m = Model::lenet_tiny();
    let zcu = by_name("zcu104").unwrap();
    let zu5 = by_name("zu5ev").unwrap();
    let max = 4;
    let spec = FleetSpec {
        entries: vec![
            FleetEntry { device: zcu.clone(), count: None },
            FleetEntry { device: zu5.clone(), count: None },
        ],
    };
    let mix = spec.plan().model(&m).max_replicas(max).run().unwrap();
    let best_single = [zcu, zu5]
        .iter()
        .filter_map(|d| {
            FleetSpec::single(d.clone(), None).plan().model(&m).max_replicas(max).run().ok()
        })
        .map(|fp| fp.fleet_img_s)
        .fold(0.0f64, f64::max);
    assert!(best_single > 0.0);
    assert!(
        mix.fleet_img_s > best_single,
        "mix {} img/s must beat best single-device {} img/s",
        mix.fleet_img_s,
        best_single
    );
    // Every group fits its own undivided part.
    for g in &mix.groups {
        assert!(g.total.fits(&g.device), "{} group must fit its part", g.device.name);
    }
}

#[test]
fn mixed_fleet_groups_run_different_ip_selections() {
    // zcu104 (DSP-rich) + edge-nodsp (4 DSPs): the per-device replica
    // plans MUST differ in conv IP selection — the DSP-starved part falls
    // back to the logic-only Conv_1 (the paper's motivating case), the
    // big part spends DSPs.
    let m = Model::lenet_tiny();
    let spec = FleetSpec {
        entries: vec![
            FleetEntry { device: by_name("zcu104").unwrap(), count: None },
            FleetEntry { device: by_name("edge-nodsp").unwrap(), count: None },
        ],
    };
    let fp = spec.plan().model(&m).max_replicas(2).run().unwrap();
    assert_eq!(fp.groups.len(), 2);
    let convs_of = |gi: usize| -> Vec<(String, u64)> {
        fp.groups[gi]
            .per_replica
            .convs()
            .map(|ep| (ep.kind.name().to_string(), ep.instances))
            .collect()
    };
    let big = convs_of(0);
    let starved = convs_of(1);
    assert_ne!(big, starved, "groups must plan different IP mixes: {big:?} vs {starved:?}");
    // The starved group uses no DSPs beyond its part's budget and leans
    // on Conv_1; the big group actually spends DSPs.
    assert!(fp.groups[1].per_replica.total.dsps <= fp.groups[1].device.dsps);
    assert!(
        starved.iter().any(|(name, _)| name == "Conv_1"),
        "edge-nodsp group must fall back to Conv_1: {starved:?}"
    );
    assert!(fp.groups[0].per_replica.total.dsps > 0, "zcu104 group should exploit DSPs");
}

#[test]
fn served_logits_bit_identical_across_device_groups() {
    // A heterogeneous fleet serves through the scheduler; every response
    // must be bit-identical to the one-shot path of EVERY group and to
    // the behavioral reference — different plans, identical arithmetic.
    let m = Model::lenet_tiny();
    let w = Weights::random(&m, 42);
    let spec = FleetSpec {
        entries: vec![
            FleetEntry { device: by_name("zcu104").unwrap(), count: Some(1) },
            FleetEntry { device: by_name("edge-nodsp").unwrap(), count: Some(1) },
        ],
    };
    let fp = spec.plan().model(&m).max_replicas(2).run().unwrap();
    let fleet = fp.deploy(m.clone(), w.clone());
    assert_eq!(fleet.replicas.len(), 2);
    let images = corpus(24, 9);
    // One-shot through each group's own pipeline.
    let per_group: Vec<Vec<Vec<i64>>> =
        fleet.replicas.iter().map(|dep| dep.infer_batch(&images).unwrap()).collect();
    // Scheduled path over the grouped server.
    let server = Server::start(fleet, &ServeConfig::default());
    let pendings: Vec<_> =
        images.iter().map(|img| server.submit_wait(img.clone()).unwrap()).collect();
    let served: Vec<Vec<i64>> = pendings.into_iter().map(|p| p.wait().unwrap()).collect();
    for (i, img) in images.iter().enumerate() {
        let reference = acf::cnn::infer::infer(&m, &w, img);
        assert_eq!(served[i], reference, "scheduled path, image {i}");
        for (gi, outs) in per_group.iter().enumerate() {
            assert_eq!(outs[i], reference, "group {gi} one-shot, image {i}");
        }
    }
    let snap = server.shutdown();
    // Only the scheduled path counts in fleet metrics; the one-shot
    // comparison batches went straight to the replicas' own pipelines.
    assert_eq!(snap.completed, 24);
    assert_eq!(snap.failed, 0);
    assert!(snap.p50_ms <= snap.p95_ms && snap.p95_ms <= snap.p99_ms);
    // The per-group breakdown accounts for exactly the scheduled images.
    assert_eq!(snap.groups.len(), 2);
    assert_eq!(snap.groups.iter().map(|g| g.images).sum::<u64>(), 24);
    assert_eq!(snap.groups.iter().map(|g| g.completed).sum::<u64>(), 24);
}

#[test]
fn coefficient_bram_overpack_is_rejected_or_downsized() {
    // Regression for the BRAM sharding bug: coefficient storage is
    // per-replica and does not shrink with the shard. A part whose BRAM
    // holds exactly two coefficient copies used to accept many replicas
    // (floor-divided BRAM looked free); now the fleet caps at two.
    let m = Model::lenet_tiny();
    let coef = acf::planner::coefficient_bram18(&m);
    assert!(coef > 0, "lenet-tiny stores coefficients");
    // Pin the catalog through the same JSON path `--catalog` uses.
    let text = format!(
        r#"[{{"name":"bramtight","part":"x-bram-tight","luts":230400,"ffs":460800,
             "clbs":28800,"dsps":1728,"bram18":{},"static_w":0.5,"speed_derate":1.0}}]"#,
        2 * coef
    );
    let extra = load_catalog(&text).unwrap();
    let spec = FleetSpec::parse("bramtight", &extra).unwrap();
    let fp = spec.plan().model(&m).max_replicas(8).run().unwrap();
    assert_eq!(fp.replicas(), 2, "BRAM holds exactly two coefficient copies");
    assert!(fp.groups[0].total.bram18 <= fp.groups[0].device.bram18);
    // Forcing a third replica is an explicit error, not silent overpack.
    let spec = FleetSpec::parse("bramtight:3", &extra).unwrap();
    let err = spec.plan().model(&m).max_replicas(8).run().unwrap_err();
    assert!(err.to_string().contains("coefficient"), "{err}");
}

#[test]
fn saturated_queue_sheds_with_overloaded() {
    // A deliberately tiny queue and single replica: a tight submission
    // loop must hit admission control, and every *accepted* request must
    // still complete correctly.
    let cfg = ServeConfig::sized(2, 1);
    let (server, model, weights) = fleet(1, &cfg);
    let images = corpus(4, 5);
    let mut accepted = Vec::new();
    let mut overloaded = 0usize;
    let mut i = 0usize;
    while overloaded == 0 && i < 10_000 {
        match server.submit(images[i % images.len()].clone()) {
            Ok(p) => accepted.push((i % images.len(), p)),
            Err(ServeError::Overloaded { queue_depth }) => {
                assert_eq!(queue_depth, 2);
                overloaded += 1;
            }
            Err(e) => panic!("unexpected error: {e}"),
        }
        i += 1;
    }
    assert!(overloaded > 0, "tight loop never tripped admission control");
    for (idx, p) in accepted {
        let logits = p.wait().unwrap();
        assert_eq!(logits, acf::cnn::infer::infer(&model, &weights, &images[idx]));
    }
    let snap = server.shutdown();
    assert_eq!(snap.rejected as usize, overloaded);
    assert_eq!(snap.completed, snap.accepted);
}

#[test]
fn two_tenant_overload_sheds_in_quota_ratio() {
    // Freeze the only replica so the per-tenant queue shares fill
    // deterministically, then offer both tenants identical demand far
    // beyond the queue. Admission capacity is the quota split (6 vs 2
    // slots), so the accepted counts must track the 3:1 quota ratio and
    // the low-quota tenant must shed a larger fraction of its offers.
    let (server, model, weights) = two_tenant_fleet();
    let replica = server.replica_ids_of_group(0)[0];
    server.inject_latency(replica, Duration::from_millis(200)).unwrap();
    let images = corpus(4, 11);
    let mut accepted = [0u64; 2];
    let mut shed = [0u64; 2];
    let mut pendings = Vec::new();
    for i in 0..100 {
        for t in 0..2 {
            match server.submit_as(t, images[i % images.len()].clone()) {
                Ok(p) => {
                    accepted[t] += 1;
                    pendings.push((i % images.len(), p));
                }
                Err(ServeError::Overloaded { .. }) => shed[t] += 1,
                Err(e) => panic!("unexpected serve error: {e}"),
            }
        }
    }
    server.clear_latency(replica);
    assert!(shed[0] > 0 && shed[1] > 0, "both tenants must overflow: {shed:?}");
    assert!(
        accepted[0] >= 2 * accepted[1],
        "gold's 3x quota must admit proportionally more: {accepted:?}"
    );
    assert!(accepted[1] >= 2, "bronze keeps its quota share of the queue: {accepted:?}");
    assert!(shed[1] > shed[0], "the low-quota tenant sheds more of equal demand: {shed:?}");
    // Everything admitted still completes bit-exactly.
    for (idx, p) in pendings {
        assert_eq!(p.wait().unwrap(), acf::cnn::infer::infer(&model, &weights, &images[idx]));
    }
    let snap = server.shutdown();
    assert_eq!(snap.tenants.len(), 2);
    let gold = &snap.tenants[0];
    let bronze = &snap.tenants[1];
    assert_eq!(gold.name, "gold");
    assert_eq!(bronze.name, "bronze");
    assert_eq!(gold.accepted, accepted[0]);
    assert_eq!(bronze.accepted, accepted[1]);
    assert_eq!(gold.completed, gold.accepted, "admission is a completion promise");
    assert_eq!(bronze.completed, bronze.accepted);
    assert!(
        bronze.shed_pct > gold.shed_pct,
        "shed rate must follow quota: bronze {} vs gold {}",
        bronze.shed_pct,
        gold.shed_pct
    );
}

#[test]
fn low_quota_tenant_is_not_starved_by_a_flood() {
    // gold floods the shared fleet; bronze's sequential requests must
    // still be admitted (its quota share is its own) and complete with a
    // sane recorded latency — weighted-fair dispatch, not strict priority.
    let (server, model, weights) = two_tenant_fleet();
    let images = corpus(4, 19);
    for i in 0..300 {
        match server.submit_as(0, images[i % images.len()].clone()) {
            Ok(_) | Err(ServeError::Overloaded { .. }) => {}
            Err(e) => panic!("unexpected serve error: {e}"),
        }
    }
    for i in 0..8 {
        let img = images[i % images.len()].clone();
        let p = server.submit_wait_as(1, img.clone()).unwrap();
        assert_eq!(p.wait().unwrap(), acf::cnn::infer::infer(&model, &weights, &img));
    }
    let snap = server.shutdown();
    let bronze = &snap.tenants[1];
    assert_eq!(bronze.name, "bronze");
    assert_eq!(bronze.accepted, 8, "sequential bronze traffic is never shed");
    assert_eq!(bronze.completed, 8);
    assert_eq!(bronze.rejected, 0);
    assert!(bronze.p99_ms > 0.0, "latency must be recorded per tenant");
    assert!(
        bronze.p99_ms < 10_000.0,
        "bronze must be served promptly, not starved: p99 {} ms",
        bronze.p99_ms
    );
    assert_eq!(snap.completed, snap.accepted, "fleet-wide completion promise holds");
}

#[test]
fn bad_requests_rejected_at_admission() {
    let (server, _, _) = fleet(1, &ServeConfig::default());
    assert!(matches!(
        server.submit(vec![0i64; 5]),
        Err(ServeError::BadRequest(acf::coordinator::DeployError::BadImage { .. }))
    ));
    let mut img = vec![0i64; 256];
    img[0] = -128;
    assert!(matches!(
        server.submit(img),
        Err(ServeError::BadRequest(acf::coordinator::DeployError::AsymmetricInput(-128)))
    ));
    let snap = server.shutdown();
    assert_eq!(snap.accepted, 0);
}

#[test]
fn shutdown_drains_accepted_requests() {
    let (server, model, weights) = fleet(2, &ServeConfig::default());
    let images = corpus(12, 13);
    let pendings: Vec<_> =
        images.iter().map(|img| server.submit_wait(img.clone()).unwrap()).collect();
    // Shut down immediately: everything admitted must still be answered.
    let snap = server.shutdown();
    assert_eq!(snap.completed, 12);
    for (img, p) in images.iter().zip(pendings) {
        assert_eq!(p.wait().unwrap(), acf::cnn::infer::infer(&model, &weights, img));
    }
    assert!(snap.replicas.iter().map(|r| r.images).sum::<u64>() == 12);
}

#[test]
fn open_loop_outcomes_are_complete_and_exact() {
    let (server, model, weights) = fleet(2, &ServeConfig::default());
    let images = corpus(16, 21);
    let outcomes = open_loop(&server, &images, 120, 5_000.0, 77);
    assert_eq!(outcomes.len(), 120);
    let mut served = 0usize;
    for o in &outcomes {
        match &o.result {
            Ok(logits) => {
                served += 1;
                assert_eq!(
                    logits,
                    &acf::cnn::infer::infer(&model, &weights, &images[o.image_idx])
                );
            }
            Err(ServeError::Overloaded { .. }) => {}
            Err(e) => panic!("unexpected serve error: {e}"),
        }
    }
    let snap = server.shutdown();
    assert_eq!(snap.completed as usize, served);
    assert_eq!((snap.accepted + snap.rejected) as usize, outcomes.len());
    if served > 0 {
        assert!(snap.sustained_img_s > 0.0);
        assert!(snap.p99_ms > 0.0);
    }
}
