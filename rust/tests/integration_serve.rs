//! Serving-tier integration tests: concurrent callers on one persistent
//! pipeline, fleet planning invariants (single-device and heterogeneous),
//! end-to-end bit-exactness of the scheduled path across device groups,
//! admission control under saturation, coefficient-BRAM honesty under
//! sharding, and drain-on-shutdown semantics.

use acf::cnn::data::Dataset;
use acf::cnn::model::{Model, Weights};
use acf::coordinator::Deployment;
use acf::fabric::device::{by_name, load_catalog};
use acf::planner::Policy;
use acf::serve::{
    open_loop, plan_fixed_fleet, plan_fleet, plan_fleet_spec, FleetEntry, FleetSpec, ServeConfig,
    ServeError, Server, DEFAULT_MAX_REPLICAS,
};
use std::sync::Arc;

fn corpus(n: usize, seed: u64) -> Vec<Vec<i64>> {
    Dataset::generate(n, seed, 16, 16).images.iter().map(|i| i.pix.clone()).collect()
}

fn deploy_one() -> Deployment {
    let m = Model::lenet_tiny();
    let w = Weights::random(&m, 42);
    let dev = by_name("zcu104").unwrap();
    Deployment::new(m, w, &dev, 200.0, &Policy::adaptive()).unwrap()
}

fn fleet(replicas: usize, cfg: &ServeConfig) -> (Server, Model, Weights) {
    let m = Model::lenet_tiny();
    let w = Weights::random(&m, 42);
    let dev = by_name("zcu104").unwrap();
    let fp = plan_fixed_fleet(&m, &dev, 200.0, &Policy::adaptive(), replicas, None).unwrap();
    let server = Server::start(fp.deploy(m.clone(), w.clone()), cfg);
    (server, m, w)
}

#[test]
fn concurrent_infer_batch_is_ordered_and_exact() {
    // Many threads hammer ONE deployment's persistent pipeline; each must
    // get its own batch back in order, bit-exact, and the shared metrics
    // must account for every image exactly once.
    let dep = Arc::new(deploy_one());
    let images = corpus(10, 3);
    let want: Vec<Vec<i64>> = images
        .iter()
        .map(|img| acf::cnn::infer::infer(&dep.model, &dep.weights, img))
        .collect();
    let threads = 8;
    let rounds = 3;
    let mut handles = Vec::new();
    for t in 0..threads {
        let dep = Arc::clone(&dep);
        let images = images.clone();
        let want = want.clone();
        handles.push(std::thread::spawn(move || {
            for r in 0..rounds {
                let mut batch = images.clone();
                let mut expect = want.clone();
                batch.rotate_left((t + r) % batch.len());
                expect.rotate_left((t + r) % expect.len());
                assert_eq!(dep.infer_batch(&batch).unwrap(), expect);
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let snap = dep.metrics.snapshot();
    assert_eq!(snap.images, (threads * rounds * images.len()) as u64);
    assert_eq!(snap.batches, (threads * rounds) as u64);
    // Every layer worker did real work.
    assert!(snap.layer_secs.iter().all(|&s| s > 0.0));
}

#[test]
fn fleet_planner_replicates_the_default_device() {
    let m = Model::lenet_tiny();
    let dev = by_name("zcu104").unwrap();
    let fp =
        plan_fleet(&m, &dev, 200.0, &Policy::adaptive(), None, DEFAULT_MAX_REPLICAS).unwrap();
    assert!(fp.replicas() >= 2, "zcu104 must carry at least two lenet-tiny replicas");
    assert_eq!(fp.groups.len(), 1);
    assert!(fp.groups[0].total.fits(&dev));
    assert!(
        (fp.fleet_img_s
            - fp.replicas() as f64 * fp.groups[0].per_replica.images_per_sec)
            .abs()
            < 1e-6,
        "fleet throughput is the replica sum"
    );
}

#[test]
fn heterogeneous_mix_beats_best_single_device_fleet() {
    // The pinned catalog: the paper's board plus a smaller sibling. The
    // mix's modeled throughput must beat the best fleet either part can
    // field alone — each part contributes its own replica group.
    let m = Model::lenet_tiny();
    let zcu = by_name("zcu104").unwrap();
    let zu5 = by_name("zu5ev").unwrap();
    let max = 4;
    let spec = FleetSpec {
        entries: vec![
            FleetEntry { device: zcu.clone(), count: None },
            FleetEntry { device: zu5.clone(), count: None },
        ],
    };
    let mix = plan_fleet_spec(&m, &spec, 200.0, &Policy::adaptive(), None, max).unwrap();
    let best_single = [zcu, zu5]
        .iter()
        .filter_map(|d| plan_fleet(&m, d, 200.0, &Policy::adaptive(), None, max).ok())
        .map(|fp| fp.fleet_img_s)
        .fold(0.0f64, f64::max);
    assert!(best_single > 0.0);
    assert!(
        mix.fleet_img_s > best_single,
        "mix {} img/s must beat best single-device {} img/s",
        mix.fleet_img_s,
        best_single
    );
    // Every group fits its own undivided part.
    for g in &mix.groups {
        assert!(g.total.fits(&g.device), "{} group must fit its part", g.device.name);
    }
}

#[test]
fn mixed_fleet_groups_run_different_ip_selections() {
    // zcu104 (DSP-rich) + edge-nodsp (4 DSPs): the per-device replica
    // plans MUST differ in conv IP selection — the DSP-starved part falls
    // back to the logic-only Conv_1 (the paper's motivating case), the
    // big part spends DSPs.
    let m = Model::lenet_tiny();
    let spec = FleetSpec {
        entries: vec![
            FleetEntry { device: by_name("zcu104").unwrap(), count: None },
            FleetEntry { device: by_name("edge-nodsp").unwrap(), count: None },
        ],
    };
    let fp = plan_fleet_spec(&m, &spec, 200.0, &Policy::adaptive(), None, 2).unwrap();
    assert_eq!(fp.groups.len(), 2);
    let convs_of = |gi: usize| -> Vec<(String, u64)> {
        fp.groups[gi]
            .per_replica
            .convs()
            .map(|ep| (ep.kind.name().to_string(), ep.instances))
            .collect()
    };
    let big = convs_of(0);
    let starved = convs_of(1);
    assert_ne!(big, starved, "groups must plan different IP mixes: {big:?} vs {starved:?}");
    // The starved group uses no DSPs beyond its part's budget and leans
    // on Conv_1; the big group actually spends DSPs.
    assert!(fp.groups[1].per_replica.total.dsps <= fp.groups[1].device.dsps);
    assert!(
        starved.iter().any(|(name, _)| name == "Conv_1"),
        "edge-nodsp group must fall back to Conv_1: {starved:?}"
    );
    assert!(fp.groups[0].per_replica.total.dsps > 0, "zcu104 group should exploit DSPs");
}

#[test]
fn served_logits_bit_identical_across_device_groups() {
    // A heterogeneous fleet serves through the scheduler; every response
    // must be bit-identical to the one-shot path of EVERY group and to
    // the behavioral reference — different plans, identical arithmetic.
    let m = Model::lenet_tiny();
    let w = Weights::random(&m, 42);
    let spec = FleetSpec {
        entries: vec![
            FleetEntry { device: by_name("zcu104").unwrap(), count: Some(1) },
            FleetEntry { device: by_name("edge-nodsp").unwrap(), count: Some(1) },
        ],
    };
    let fp = plan_fleet_spec(&m, &spec, 200.0, &Policy::adaptive(), None, 2).unwrap();
    let replicas = fp.deploy(m.clone(), w.clone());
    assert_eq!(replicas.len(), 2);
    let images = corpus(24, 9);
    // One-shot through each group's own pipeline.
    let per_group: Vec<Vec<Vec<i64>>> =
        replicas.iter().map(|dep| dep.infer_batch(&images).unwrap()).collect();
    // Scheduled path over the grouped server.
    let server = Server::start_grouped(
        replicas,
        fp.replica_groups(),
        fp.group_labels(),
        &ServeConfig::default(),
    );
    let pendings: Vec<_> =
        images.iter().map(|img| server.submit_wait(img.clone()).unwrap()).collect();
    let served: Vec<Vec<i64>> = pendings.into_iter().map(|p| p.wait().unwrap()).collect();
    for (i, img) in images.iter().enumerate() {
        let reference = acf::cnn::infer::infer(&m, &w, img);
        assert_eq!(served[i], reference, "scheduled path, image {i}");
        for (gi, outs) in per_group.iter().enumerate() {
            assert_eq!(outs[i], reference, "group {gi} one-shot, image {i}");
        }
    }
    let snap = server.shutdown();
    // Only the scheduled path counts in fleet metrics; the one-shot
    // comparison batches went straight to the replicas' own pipelines.
    assert_eq!(snap.completed, 24);
    assert_eq!(snap.failed, 0);
    assert!(snap.p50_ms <= snap.p95_ms && snap.p95_ms <= snap.p99_ms);
    // The per-group breakdown accounts for exactly the scheduled images.
    assert_eq!(snap.groups.len(), 2);
    assert_eq!(snap.groups.iter().map(|g| g.images).sum::<u64>(), 24);
    assert_eq!(snap.groups.iter().map(|g| g.completed).sum::<u64>(), 24);
}

#[test]
fn coefficient_bram_overpack_is_rejected_or_downsized() {
    // Regression for the BRAM sharding bug: coefficient storage is
    // per-replica and does not shrink with the shard. A part whose BRAM
    // holds exactly two coefficient copies used to accept many replicas
    // (floor-divided BRAM looked free); now the fleet caps at two.
    let m = Model::lenet_tiny();
    let coef = acf::planner::coefficient_bram18(&m);
    assert!(coef > 0, "lenet-tiny stores coefficients");
    // Pin the catalog through the same JSON path `--catalog` uses.
    let text = format!(
        r#"[{{"name":"bramtight","part":"x-bram-tight","luts":230400,"ffs":460800,
             "clbs":28800,"dsps":1728,"bram18":{},"static_w":0.5,"speed_derate":1.0}}]"#,
        2 * coef
    );
    let extra = load_catalog(&text).unwrap();
    let spec = FleetSpec::parse("bramtight", &extra).unwrap();
    let fp = plan_fleet_spec(&m, &spec, 200.0, &Policy::adaptive(), None, 8).unwrap();
    assert_eq!(fp.replicas(), 2, "BRAM holds exactly two coefficient copies");
    assert!(fp.groups[0].total.bram18 <= fp.groups[0].device.bram18);
    // Forcing a third replica is an explicit error, not silent overpack.
    let spec = FleetSpec::parse("bramtight:3", &extra).unwrap();
    let err = plan_fleet_spec(&m, &spec, 200.0, &Policy::adaptive(), None, 8).unwrap_err();
    assert!(err.to_string().contains("coefficient"), "{err}");
}

#[test]
fn saturated_queue_sheds_with_overloaded() {
    // A deliberately tiny queue and single replica: a tight submission
    // loop must hit admission control, and every *accepted* request must
    // still complete correctly.
    let cfg = ServeConfig { queue_depth: 2, max_batch: 1, ..ServeConfig::default() };
    let (server, model, weights) = fleet(1, &cfg);
    let images = corpus(4, 5);
    let mut accepted = Vec::new();
    let mut overloaded = 0usize;
    let mut i = 0usize;
    while overloaded == 0 && i < 10_000 {
        match server.submit(images[i % images.len()].clone()) {
            Ok(p) => accepted.push((i % images.len(), p)),
            Err(ServeError::Overloaded { queue_depth }) => {
                assert_eq!(queue_depth, 2);
                overloaded += 1;
            }
            Err(e) => panic!("unexpected error: {e}"),
        }
        i += 1;
    }
    assert!(overloaded > 0, "tight loop never tripped admission control");
    for (idx, p) in accepted {
        let logits = p.wait().unwrap();
        assert_eq!(logits, acf::cnn::infer::infer(&model, &weights, &images[idx]));
    }
    let snap = server.shutdown();
    assert_eq!(snap.rejected as usize, overloaded);
    assert_eq!(snap.completed, snap.accepted);
}

#[test]
fn bad_requests_rejected_at_admission() {
    let (server, _, _) = fleet(1, &ServeConfig::default());
    assert!(matches!(
        server.submit(vec![0i64; 5]),
        Err(ServeError::BadRequest(acf::coordinator::DeployError::BadImage { .. }))
    ));
    let mut img = vec![0i64; 256];
    img[0] = -128;
    assert!(matches!(
        server.submit(img),
        Err(ServeError::BadRequest(acf::coordinator::DeployError::AsymmetricInput(-128)))
    ));
    let snap = server.shutdown();
    assert_eq!(snap.accepted, 0);
}

#[test]
fn shutdown_drains_accepted_requests() {
    let (server, model, weights) = fleet(2, &ServeConfig::default());
    let images = corpus(12, 13);
    let pendings: Vec<_> =
        images.iter().map(|img| server.submit_wait(img.clone()).unwrap()).collect();
    // Shut down immediately: everything admitted must still be answered.
    let snap = server.shutdown();
    assert_eq!(snap.completed, 12);
    for (img, p) in images.iter().zip(pendings) {
        assert_eq!(p.wait().unwrap(), acf::cnn::infer::infer(&model, &weights, img));
    }
    assert!(snap.replicas.iter().map(|r| r.images).sum::<u64>() == 12);
}

#[test]
fn open_loop_outcomes_are_complete_and_exact() {
    let (server, model, weights) = fleet(2, &ServeConfig::default());
    let images = corpus(16, 21);
    let outcomes = open_loop(&server, &images, 120, 5_000.0, 77);
    assert_eq!(outcomes.len(), 120);
    let mut served = 0usize;
    for o in &outcomes {
        match &o.result {
            Ok(logits) => {
                served += 1;
                assert_eq!(
                    logits,
                    &acf::cnn::infer::infer(&model, &weights, &images[o.image_idx])
                );
            }
            Err(ServeError::Overloaded { .. }) => {}
            Err(e) => panic!("unexpected serve error: {e}"),
        }
    }
    let snap = server.shutdown();
    assert_eq!(snap.completed as usize, served);
    assert_eq!((snap.accepted + snap.rejected) as usize, outcomes.len());
    if served > 0 {
        assert!(snap.sustained_img_s > 0.0);
        assert!(snap.p99_ms > 0.0);
    }
}
