//! Cross-layer integration: the AOT-compiled JAX/Pallas artifacts
//! (executed through PJRT) must agree bit-for-bit with the Rust
//! behavioral stack and the deployed coordinator pipeline.
//!
//! Requires `make artifacts` and the `xla` cargo feature (the PJRT
//! runtime is a stub without it — these tests compile to nothing then).
#![cfg(feature = "xla")]

use acf::cnn::data::Dataset;
use acf::cnn::infer::{argmax, infer};
use acf::cnn::model::{Model, Weights};
use acf::coordinator::Deployment;
use acf::fabric::device::by_name;
use acf::planner::Policy;
use acf::runtime::{self, cpu_client, GoldenCnn, WindowKernel};
use acf::util::rng::Rng;

fn art_dir() -> std::path::PathBuf {
    runtime::find_artifacts().expect(
        "artifacts/ not found — run `make artifacts` before `cargo test` (the Makefile does)",
    )
}

#[test]
fn weights_json_matches_rust_rng_port() {
    // aot.py derives weights through the Python port of our xorshift64*;
    // both sides must produce identical values from the seed.
    let model = Model::lenet_tiny();
    let ours = Weights::random(&model, runtime::AOT_WEIGHT_SEED);
    let theirs = runtime::load_weights(&art_dir()).expect("weights.json loads");
    assert_eq!(ours, theirs, "rng port drifted between rust and python");
}

#[test]
fn window_kernel_matches_fixed_point_reference() {
    let client = cpu_client().unwrap();
    let wk = WindowKernel::load(&client, &art_dir()).unwrap();
    let params = acf::ips::ConvParams::paper_8bit();
    let mut rng = Rng::new(0xA0A0);
    for trial in 0..200 {
        let mut win = [0i64; 9];
        let mut coef = [0i64; 9];
        for i in 0..9 {
            win[i] = rng.signed_bits(8);
            coef[i] = rng.signed_bits(8);
        }
        let got = wk.eval(&win, &coef).unwrap();
        let want = params.window_ref(&win, &coef);
        assert_eq!(got, want, "trial {trial}: win={win:?} coef={coef:?}");
    }
    // Saturation corners.
    let hi = [127i64; 9];
    let lo = [-128i64; 9];
    assert_eq!(wk.eval(&hi, &hi).unwrap(), 127);
    assert_eq!(wk.eval(&hi, &lo).unwrap(), -128);
}

#[test]
fn golden_cnn_matches_behavioral_inference() {
    let client = cpu_client().unwrap();
    let art = art_dir();
    let golden = GoldenCnn::load(&client, &art).unwrap();
    let model = Model::lenet_tiny();
    let weights = runtime::load_weights(&art).unwrap();
    let ds = Dataset::generate(20, 77, 16, 16);
    for img in &ds.images {
        let want = infer(&model, &weights, &img.pix);
        let got = golden.infer(&img.pix).unwrap();
        assert_eq!(got, want, "image label {}", img.label);
    }
}

#[test]
fn deployed_pipeline_matches_golden_end_to_end() {
    // The full chain: coordinator (threaded, planned IPs, behavioral
    // models verified against netlists) == XLA(JAX/Pallas) golden.
    let client = cpu_client().unwrap();
    let art = art_dir();
    let golden = GoldenCnn::load(&client, &art).unwrap();
    let model = Model::lenet_tiny();
    let weights = runtime::load_weights(&art).unwrap();
    let dev = by_name("zcu104").unwrap();
    let dep = Deployment::new(model, weights, &dev, 200.0, &Policy::adaptive()).unwrap();
    let ds = Dataset::generate(16, 123, 16, 16);
    let images: Vec<Vec<i64>> = ds.images.iter().map(|i| i.pix.clone()).collect();
    let fabric = dep.infer_batch(&images).unwrap();
    let mut agree = 0;
    for (img, fab) in images.iter().zip(&fabric) {
        let gold = golden.infer(img).unwrap();
        assert_eq!(fab, &gold, "logits must be bit-identical");
        if argmax(fab) == argmax(&gold) {
            agree += 1;
        }
    }
    assert_eq!(agree, images.len());
}

#[test]
fn golden_rejects_bad_shapes() {
    let client = cpu_client().unwrap();
    let golden = GoldenCnn::load(&client, &art_dir()).unwrap();
    assert!(golden.infer(&[0i64; 7]).is_err());
}
