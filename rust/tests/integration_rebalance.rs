//! Dynamic-rebalancing integration tests: the step-load contract (the
//! fleet grows under a spike and shrinks back in the lull, with zero
//! dropped in-flight requests and bit-exact outputs throughout), and the
//! replica add/retire lifecycle underneath it (weighted-drain handoff,
//! last-replica protection, drain summaries).

use acf::cnn::data::Dataset;
use acf::cnn::model::{Model, Weights};
use acf::coordinator::Deployment;
use acf::fabric::device::by_name;
use acf::planner::Policy;
use acf::serve::{
    FleetFrontier, FleetSpec, RebalanceAction, RebalanceConfig, Rebalancer, ServeConfig,
    ServeError, Server,
};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

fn corpus(n: usize, seed: u64) -> Vec<Vec<i64>> {
    Dataset::generate(n, seed, 16, 16).images.iter().map(|i| i.pix.clone()).collect()
}

/// Poll `cond` until it holds or `timeout` expires; returns whether it
/// held.
fn wait_for(timeout: Duration, mut cond: impl FnMut() -> bool) -> bool {
    let deadline = Instant::now() + timeout;
    while Instant::now() < deadline {
        if cond() {
            return true;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    cond()
}

#[test]
fn step_load_grows_under_spike_and_shrinks_back() {
    // One zcu104 group, started at ONE replica although the frontier
    // holds more — the spike must pull extra replicas in, the lull must
    // retire them, and every admitted request must complete bit-exactly.
    let m = Model::lenet_tiny();
    let w = Weights::random(&m, 42);
    let spec = FleetSpec::single(by_name("zcu104").unwrap(), None);
    let frontier = FleetFrontier::build(&m, &spec, 200.0, &Policy::adaptive(), 3).unwrap();
    assert!(frontier.groups[0].max_count() >= 2, "zcu104 must hold at least two replicas");
    let fp = frontier.fleet_at(&[1]);
    assert_eq!(fp.replicas(), 1);

    let model = Arc::new(m.clone());
    let weights = Arc::new(w.clone());
    let cfg = ServeConfig::sized(8, 4);
    let server = Arc::new(Server::start(
        fp.deploy_shared(Arc::clone(&model), Arc::clone(&weights)),
        &cfg,
    ));
    let rb = Rebalancer::start(
        Arc::clone(&server),
        frontier,
        &fp,
        vec![Arc::clone(&weights)],
        RebalanceConfig {
            window: Duration::from_millis(100),
            headroom: 0.25,
            cooldown: Duration::from_millis(150),
            min_replicas: 1,
        },
    );

    let images = corpus(12, 9);
    let refs: Vec<Vec<i64>> =
        images.iter().map(|img| acf::cnn::infer::infer(&m, &w, img)).collect();

    // Phase 1 — low load: a few closed-loop requests, all exact.
    for (i, img) in images.iter().take(4).enumerate() {
        let logits = server.submit_wait(img.clone()).unwrap().wait().unwrap();
        assert_eq!(logits, refs[i], "low-phase image {i}");
        std::thread::sleep(Duration::from_millis(15));
    }

    // Phase 2 — spike: saturate the single replica from many closed-loop
    // threads until the controller scales the group up.
    let stop = Arc::new(AtomicBool::new(false));
    let mut spikers = Vec::new();
    for t in 0..8usize {
        let server = Arc::clone(&server);
        let stop = Arc::clone(&stop);
        let images = images.clone();
        let refs = refs.clone();
        spikers.push(std::thread::spawn(move || {
            let mut sent = 0usize;
            let mut k = t;
            while !stop.load(Ordering::Relaxed) {
                let idx = k % images.len();
                k += 1;
                let logits = server.submit_wait(images[idx].clone()).unwrap().wait().unwrap();
                assert_eq!(logits, refs[idx], "spike thread {t} request {sent}");
                sent += 1;
            }
            sent
        }));
    }
    let grew = wait_for(Duration::from_secs(20), || {
        server.live_counts()[0] > 1
    });
    stop.store(true, Ordering::Relaxed);
    let spike_sent: usize = spikers.into_iter().map(|h| h.join().unwrap()).sum();
    assert!(grew, "fleet never scaled up under the spike");
    assert!(spike_sent > 0, "spike threads must have exercised the fleet");

    // Phase 3 — lull: zero traffic; the controller must shrink back to
    // one replica (one step per cooldown).
    let shrank = wait_for(Duration::from_secs(20), || server.live_counts()[0] == 1);
    assert!(shrank, "fleet never shrank back in the lull: {:?}", server.live_counts());

    // A little post-shrink traffic still serves bit-exactly.
    for (i, img) in images.iter().take(4).enumerate() {
        let logits = server.submit_wait(img.clone()).unwrap().wait().unwrap();
        assert_eq!(logits, refs[i], "post-shrink image {i}");
    }

    rb.stop();
    let snap = server.shutdown();
    // Zero dropped in-flight requests: everything admitted completed.
    assert_eq!(snap.completed, snap.accepted, "admitted requests must all complete");
    assert_eq!(snap.failed, 0);
    // The timeline shows both directions.
    let acted = |a: RebalanceAction, b: RebalanceAction| {
        snap.events.iter().any(|e| e.action == a || e.action == b)
    };
    assert!(
        acted(RebalanceAction::Grow, RebalanceAction::Swap),
        "no grow/swap event: {:?}",
        snap.events
    );
    assert!(
        acted(RebalanceAction::Shrink, RebalanceAction::Swap),
        "no shrink/swap event: {:?}",
        snap.events
    );
    // Churn really happened and every retirement drained cleanly.
    let g = &snap.groups[0];
    assert!(g.spawned > 1, "spike must have spawned extra replicas");
    assert_eq!(g.drain_failed, 0, "no replica may miss its drain deadline");
    assert_eq!(g.drain_leftover_images, 0);
    assert!(g.drained >= g.spawned, "every replica (live ones at shutdown included) drains");
}

#[test]
fn replicas_add_and_retire_under_live_traffic() {
    let m = Model::lenet_tiny();
    let w = Weights::random(&m, 42);
    let dev = by_name("zcu104").unwrap();
    let fp = FleetSpec::single(dev, Some(2)).plan().model(&m).run().unwrap();
    let model = Arc::new(m.clone());
    let weights = Arc::new(w.clone());
    let server = Server::start(
        fp.deploy_shared(Arc::clone(&model), Arc::clone(&weights)),
        &ServeConfig::default(),
    );
    assert_eq!(server.live_counts(), vec![2]);

    // Work in flight across both replicas...
    let images = corpus(10, 21);
    let pendings: Vec<_> =
        images.iter().map(|img| server.submit_wait(img.clone()).unwrap()).collect();

    // ...while one of them retires: the weighted-drain handoff must let
    // its queued micro-batches finish before teardown.
    let victim = server.replica_ids_of_group(0)[0];
    let report = server.retire_replica(victim).unwrap();
    assert!(report.drained, "replica must drain within the default deadline");
    assert_eq!(report.leftover, 0);
    assert_eq!(server.live_counts(), vec![1]);
    // Retiring the last live replica is refused.
    let last = server.replica_ids_of_group(0)[0];
    assert!(matches!(server.retire_replica(last), Err(ServeError::Rebalance(_))));
    // Unknown / already-retired ids are refused too (after adding a
    // second replica so the guard above is not what trips).
    let dep = Arc::new(Deployment::with_plan(
        Arc::clone(&model),
        Arc::clone(&weights),
        fp.groups[0].per_replica.clone(),
    ));
    let added = server.add_replica(dep, 0).unwrap();
    assert_eq!(server.live_counts(), vec![2]);
    assert!(matches!(server.retire_replica(victim), Err(ServeError::Rebalance(_))));

    // Everything admitted before and during the churn completes exactly.
    for (img, p) in images.iter().zip(pendings) {
        assert_eq!(p.wait().unwrap(), acf::cnn::infer::infer(&m, &w, img));
    }
    // And the refreshed fleet serves new traffic on the added replica.
    let extra: Vec<_> =
        images.iter().map(|img| server.submit_wait(img.clone()).unwrap()).collect();
    for (img, p) in images.iter().zip(extra) {
        assert_eq!(p.wait().unwrap(), acf::cnn::infer::infer(&m, &w, img));
    }

    let snap = server.shutdown();
    assert_eq!(snap.completed, snap.accepted);
    assert_eq!(snap.failed, 0);
    let g = &snap.groups[0];
    assert_eq!(g.spawned, 3, "2 initial + 1 added");
    assert_eq!(g.drain_failed, 0);
    // 1 live retirement + 2 live replicas reaped at shutdown.
    assert_eq!(g.drained, 3);
    // The retired replica's history survives, flagged.
    assert!(snap.replicas[victim].retired);
    assert_eq!(snap.replicas.len(), 3);
    assert!(added < snap.replicas.len());
    // Shutdown is idempotent.
    let again = server.shutdown();
    assert_eq!(again.completed, snap.completed);
}
